//! Kernel microbench snapshot — machine-readable perf trajectory.
//!
//! Runs the kernel-core microbenches at fixed shapes (n ∈ {1024, 4096},
//! c = 64, d = 64) and writes `BENCH_kernels.json` at the repo root
//! (falling back to the crate root when run elsewhere): variant →
//! ns/op, GF/s, threads, fast-vs-seed-scalar speedups, plus the
//! serving-path entries (schema v6): CPU-backend coordinator
//! requests/sec per encoder depth (`cpu_encode_rps_n{N}_l{L}` for
//! n ∈ {1024, 4096} × layers ∈ {1, 4} — layer 1 is the seed
//! single-pass model, layer 4 the full pre-LN stack), and a
//! mixed-deadline workload over a 4-worker pool with the embedding
//! cache on — cache hit rate, per-request p50/p99 e2e latency, and
//! deadline expiries. Model defaults (d/heads/landmarks/ffn_mult) are
//! recorded alongside the rates. CI and future PRs diff this file to
//! track the hot path.
//!
//! Schema v6 adds the per-ISA dispatch rows: `isa.gemm_gflops_<arm>`
//! (GEMM GF/s with the kernel core pinned to each arm this host can
//! run) and `isa.serving_rps_<arm>` (layers=1 coordinator throughput
//! per arm via the `[serving] kernel` knob), plus `kernel_active` /
//! `kernel_available` metadata — the SIMD speedup lands
//! machine-readably next to the numbers it multiplies.
//!
//! Schema v7 adds the cluster-tier rows under `"cluster"`: a router
//! front-end over two loopback replicas, reporting forwarded
//! requests/sec through the consistent-hash hop
//! (`router_forward_rps`), replayed requests/sec served from the
//! router's cross-replica cache (`router_cache_hit_rps`), and the
//! router cache hit rate over the whole workload — the serving tier's
//! horizontal-scaling counterpart of the `cpu_encode_rps_*` rows.
//!
//! Schema v8 adds the streaming long-document rows under `"longdoc"`:
//! documents past the largest bucket served over TCP through the
//! chunked ENCODE path with the prefix-reuse cache on, over a trace
//! whose documents share a multi-chunk template prefix (≥50% chunk
//! overlap). Reports the chunk hit rate, per-chunk amortized latency,
//! client-side p50/p99 per document, and documents/sec — the
//! trajectory rows for the chunk-granular reuse path.
//!
//! Schema v9 adds the precision-tier rows under `"quant"`:
//! `gemm_gflops_n{N}_<tier>` (the GEMM shape through
//! `gemm_quant_into` with the weights quantized to each tier, f32 as
//! the baseline row) and `serving_rps_n{N}_l1_<tier>` (layers=1
//! coordinator throughput with the `[serving] admission` knob forcing
//! every request onto each tier) — the perf half of the accuracy/perf
//! trade the admission policy sells, next to the error half in
//! `BENCH_error_bound.json`.
//!
//! Run: cargo bench --bench bench_snapshot
//! Threads: set SSAFORMER_THREADS to pin the pool size.
//! Smoke mode: set BENCH_SMOKE=1 to shrink the problem set (n = 256
//! only, shorter timing budgets) so CI can regenerate the JSON per
//! commit in seconds; the output records `"smoke": true` so trajectory
//! tooling never compares smoke numbers against full runs.

use ssaformer::attention::spectral_shift::reference;
use ssaformer::attention::{
    matmul_f32, nystrom_attention_with, spectral_shift_attention_with,
    SpectralShiftConfig, Tensor2,
};
use ssaformer::benchkit::{banner, bench, fmt_duration, Table};
use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::cluster::{
    serve_router, ClusterConfig, ClusterRouter,
};
use ssaformer::coordinator::{
    Coordinator, CpuEngine, CpuModel, CpuModelConfig, EncodeRequest,
    ExecBackend, TierKind,
};
use ssaformer::server::{serve, Client};
use ssaformer::kernels::{
    active_isa, gemm_f32, gemm_quant_into, global_pool, Isa, KernelCtx,
    Precision, QuantMatrix, Workspace,
};
use ssaformer::rngx::Rng;
use std::sync::Arc;
use std::time::Duration;

struct Entry {
    name: String,
    n: usize,
    ns_per_op: f64,
    gflops: f64,
    threads: usize,
}

/// CI smoke mode: reduced shapes, same schema (flagged in the JSON).
fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

fn main() {
    let threads = global_pool().size() + 1; // workers + contributing caller
    let sizes: &[usize] = if smoke() { &[256] } else { &[1024, 4096] };
    banner("bench_snapshot — kernel core at fixed shapes",
           &format!("n ∈ {sizes:?}{}, c = 64, d = 64, f32; \
                     {threads} kernel threads.\nWrites BENCH_kernels.json \
                     (variant → ns/op, GF/s, threads).",
                    if smoke() { " (BENCH_SMOKE)" } else { "" }));

    let (c, d) = (64usize, 64usize);
    let budget = Duration::from_millis(if smoke() { 120 } else { 700 });
    let seq = KernelCtx::sequential();
    let par = KernelCtx::global();
    let mut entries: Vec<Entry> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();
    // per-ISA dispatch rows (schema v6): keyed by arm token
    let mut isa_rows: Vec<(String, f64)> = Vec::new();
    // precision-tier rows (schema v9): quantized GEMM GF/s and
    // forced-admission serving rps, keyed by tier token
    let mut quant: Vec<(String, f64)> = Vec::new();

    let mut table = Table::new(&["kernel", "n", "median", "GF/s", "threads"]);
    for &n in sizes {
        let mut rng = Rng::new(n as u64);
        let q = Tensor2::randn(&mut rng, n, d, 1.0);
        let k = Tensor2::randn(&mut rng, n, d, 1.0);
        let v = Tensor2::randn(&mut rng, n, d, 1.0);
        let cfg = SpectralShiftConfig::new(c);
        let mut ws = Workspace::new();

        // --- GEMM microbench: (n×d)·(d×d), the F/W factor shape class
        let b = Tensor2::randn(&mut rng, d, d, 1.0);
        let gemm_flops = (2 * n * d * d) as f64;
        let s = bench(|| {
            let out = matmul_f32(&q, &b);
            std::hint::black_box(&out);
        }, budget, 40);
        push(&mut entries, &mut table, "gemm/ref_scalar", n, &s, gemm_flops, 1);
        let ref_gemm = s.median.as_secs_f64();

        let s = bench(|| {
            let out = gemm_f32(&seq, &q, &b, &mut ws);
            std::hint::black_box(&out.data);
            ws.put(out.data);
        }, budget, 60);
        push(&mut entries, &mut table, "gemm/fast_t1", n, &s, gemm_flops, 1);

        let s = bench(|| {
            let out = gemm_f32(&par, &q, &b, &mut ws);
            std::hint::black_box(&out.data);
            ws.put(out.data);
        }, budget, 60);
        push(&mut entries, &mut table, "gemm/fast_tN", n, &s, gemm_flops, threads);
        speedups.push((format!("gemm_n{n}_fast_tN_vs_ref"),
                       ref_gemm / s.median.as_secs_f64()));
        let f32_gemm_gflops = gemm_flops / s.median.as_secs_f64() / 1e9;

        // --- per-ISA GEMM rows: the same shape with the kernel core
        // pinned to each arm this host can run (scalar is always one)
        for isa in Isa::available() {
            let ctx = par.clone().with_isa(isa);
            let s = bench(|| {
                let out = gemm_f32(&ctx, &q, &b, &mut ws);
                std::hint::black_box(&out.data);
                ws.put(out.data);
            }, budget, 60);
            let name = format!("gemm/arm_{}", isa.token());
            push(&mut entries, &mut table, &name, n, &s, gemm_flops, threads);
            isa_rows.push((format!("gemm_gflops_n{n}_{}", isa.token()),
                           gemm_flops / s.median.as_secs_f64() / 1e9));
        }

        // --- precision-tier GEMM rows (schema v9): the same shape
        // through `gemm_quant_into` with B held at each quantized tier;
        // the f32 baseline row repeats the fast_tN number so the three
        // rows diff against each other directly
        quant.push((format!("gemm_gflops_n{n}_f32"), f32_gemm_gflops));
        for p in [Precision::Bf16, Precision::Int8] {
            let bq = QuantMatrix::quantize(&b.data, d, d, p);
            let mut out = vec![0.0f32; n * d];
            let s = bench(|| {
                gemm_quant_into(&par, &q.data, &bq, &mut out, n, d, d,
                                &mut ws);
                std::hint::black_box(&out);
            }, budget, 60);
            let name = format!("gemm/quant_{}", p.token());
            push(&mut entries, &mut table, &name, n, &s, gemm_flops, threads);
            quant.push((format!("gemm_gflops_n{n}_{}", p.token()),
                        gemm_flops / s.median.as_secs_f64() / 1e9));
        }

        // --- spectral shifting end-to-end, seed scalar vs kernel core
        // flop model (approx): F logits + fused combine + W stream
        // (score dot + value axpy) + pinv iterations
        let ss_flops = (8 * n * c * d + cfg.pinv_iters * 8 * c * c * c) as f64;
        let s = bench(|| {
            let out = reference::spectral_shift_attention_ref(&q, &k, &v, &cfg);
            std::hint::black_box(&out);
        }, budget, 20);
        push(&mut entries, &mut table, "spectral_shift/ref_scalar", n, &s, ss_flops, 1);
        let ref_ss = s.median.as_secs_f64();

        let s = bench(|| {
            let out = spectral_shift_attention_with(&q, &k, &v, &cfg, &seq, &mut ws);
            std::hint::black_box(&out.data);
            ws.put(out.data);
        }, budget, 30);
        push(&mut entries, &mut table, "spectral_shift/fast_t1", n, &s, ss_flops, 1);
        speedups.push((format!("spectral_shift_n{n}_fast_t1_vs_ref"),
                       ref_ss / s.median.as_secs_f64()));

        let s = bench(|| {
            let out = spectral_shift_attention_with(&q, &k, &v, &cfg, &par, &mut ws);
            std::hint::black_box(&out.data);
            ws.put(out.data);
        }, budget, 30);
        push(&mut entries, &mut table, "spectral_shift/fast_tN", n, &s, ss_flops, threads);
        speedups.push((format!("spectral_shift_n{n}_fast_tN_vs_ref"),
                       ref_ss / s.median.as_secs_f64()));

        // --- Nystromformer on the same core (baseline sanity)
        let s = bench(|| {
            let out = nystrom_attention_with(&q, &k, &v, c, 8, None, &par, &mut ws);
            std::hint::black_box(&out.data);
            ws.put(out.data);
        }, budget, 30);
        push(&mut entries, &mut table, "nystrom/fast_tN", n, &s, ss_flops, threads);
    }
    println!("{}", table.render());

    let mut spd = Table::new(&["speedup", "×"]);
    for (name, x) in &speedups {
        spd.row(&[name.clone(), format!("{x:.2}")]);
    }
    println!("{}", spd.render());

    // --- serving path: requests/sec through the CPU-backend coordinator
    // (submit → bucket queue → batcher → kernels::batched → pooled
    // embedding), saturated offered load at a single bucket
    // serving rows use the CPU model defaults, NOT the kernel-bench
    // c/d above — record them so the JSON is self-describing
    let mcfg = CpuModelConfig::default();
    let mut serving: Vec<(String, f64)> = vec![
        ("model_d".into(), mcfg.d_model as f64),
        ("model_heads".into(), mcfg.n_heads as f64),
        ("model_landmarks".into(), mcfg.landmarks as f64),
        ("model_ffn_mult".into(), mcfg.ffn_mult as f64),
    ];
    let mut stbl = Table::new(&["serving (cpu backend)", "layers", "n", "req/s"]);
    for &layers in &[1usize, 4] {
        for &n in sizes {
            let cfg = ServingConfig {
                variant: Variant::SpectralShift,
                layers,
                max_batch: 4,
                max_wait_ms: 2,
                queue_capacity: 256,
                seq_buckets: sizes.to_vec(),
                // cache off: this row measures the *encode* path, and
                // the saturated load replays one token sequence
                cache_capacity: 0,
                ..Default::default()
            };
            let engine = Box::new(CpuEngine::new(CpuModel::new(
                CpuModelConfig { layers, ..Default::default() }, cfg.variant)));
            let coordinator = Arc::new(
                Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
            let toks: Vec<i32> = (0..n).map(|i| 3 + (i as i32 % 2000)).collect();
            // warm the kernel arenas before timing
            coordinator.submit_blocking(toks.clone()).unwrap().embedding.unwrap();
            let reqs = if smoke() { 8 } else { 24 };
            let start = std::time::Instant::now();
            let rxs: Vec<_> = (0..reqs)
                .map(|_| coordinator.submit(toks.clone()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap().embedding.unwrap();
            }
            let rps = reqs as f64 / start.elapsed().as_secs_f64();
            stbl.row(&["encode_rps".into(), layers.to_string(), n.to_string(),
                       format!("{rps:.1}")]);
            serving.push((format!("cpu_encode_rps_n{n}_l{layers}"), rps));
        }
    }
    // per-ISA serving rows (schema v6): layers=1 at the smallest bucket
    // with the `[serving] kernel` knob pinning each available arm — the
    // end-to-end counterpart of the gemm_gflops_* rows
    {
        let n = sizes[0];
        for isa in Isa::available() {
            let cfg = ServingConfig {
                variant: Variant::SpectralShift,
                layers: 1,
                max_batch: 4,
                max_wait_ms: 2,
                queue_capacity: 256,
                seq_buckets: sizes.to_vec(),
                cache_capacity: 0,
                kernel: Some(isa),
                ..Default::default()
            };
            let engine = Box::new(CpuEngine::new(CpuModel::new(
                CpuModelConfig::default(), cfg.variant)));
            let coordinator = Arc::new(
                Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
            let toks: Vec<i32> = (0..n).map(|i| 3 + (i as i32 % 2000)).collect();
            coordinator.submit_blocking(toks.clone()).unwrap().embedding.unwrap();
            let reqs = if smoke() { 8 } else { 24 };
            let start = std::time::Instant::now();
            let rxs: Vec<_> = (0..reqs)
                .map(|_| coordinator.submit(toks.clone()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap().embedding.unwrap();
            }
            let rps = reqs as f64 / start.elapsed().as_secs_f64();
            stbl.row(&[format!("encode_rps[{}]", isa.token()), "1".into(),
                       n.to_string(), format!("{rps:.1}")]);
            isa_rows.push((format!("serving_rps_n{n}_l1_{}", isa.token()), rps));
        }
    }
    // per-tier serving rows (schema v9): layers=1 at the smallest bucket
    // with the `[serving] admission` knob forcing every request onto
    // each tier — the end-to-end counterpart of the quant GEMM rows and
    // the perf half of the trade priced in BENCH_error_bound.json
    {
        let n = sizes[0];
        for tier in TierKind::ALL {
            let cfg = ServingConfig {
                variant: Variant::SpectralShift,
                layers: 1,
                max_batch: 4,
                max_wait_ms: 2,
                queue_capacity: 256,
                seq_buckets: sizes.to_vec(),
                cache_capacity: 0,
                admission: Some(tier),
                ..Default::default()
            };
            let engine = Box::new(CpuEngine::new(CpuModel::new(
                CpuModelConfig::default(), cfg.variant)));
            let coordinator = Arc::new(
                Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
            let toks: Vec<i32> = (0..n).map(|i| 3 + (i as i32 % 2000)).collect();
            coordinator.submit_blocking(toks.clone()).unwrap().embedding.unwrap();
            let reqs = if smoke() { 8 } else { 24 };
            let start = std::time::Instant::now();
            let rxs: Vec<_> = (0..reqs)
                .map(|_| coordinator.submit(toks.clone()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap().embedding.unwrap();
            }
            let rps = reqs as f64 / start.elapsed().as_secs_f64();
            stbl.row(&[format!("encode_rps[{}]", tier.token()), "1".into(),
                       n.to_string(), format!("{rps:.1}")]);
            quant.push((format!("serving_rps_n{n}_l1_{}", tier.token()), rps));
        }
    }
    println!("{}", stbl.render());

    // --- mixed-deadline workload over the sharded worker pool + cache
    // (schema v3): 16 distinct sequences replayed 3× from 4 client
    // threads, one deliberately-expired deadline per thread — reports
    // cache hit rate, per-request p50/p99, and expiry count
    {
        let cfg = ServingConfig {
            variant: Variant::SpectralShift,
            max_batch: 4,
            max_wait_ms: 2,
            queue_capacity: 256,
            seq_buckets: vec![256, 512],
            workers: 4,
            queue_shards: 2,
            cache_capacity: 64,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), cfg.variant)));
        let coordinator = Arc::new(
            Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
        // warm the arenas off the clock; counters are snapshotted after
        // so the warm-up pollutes neither rates nor percentiles (the
        // e2e percentiles below are measured client-side, per timed
        // request, for the same reason — the coordinator histogram is
        // cumulative and would fold the cold warm-up into p99)
        let warm: Vec<i32> = (0..256).map(|i| 7 + (i as i32 % 999)).collect();
        coordinator.submit_blocking(warm).unwrap().embedding.unwrap();
        let m = &coordinator.metrics;
        let (hits0, misses0, expired0) =
            (m.cache_hits.get(), m.cache_misses.get(), m.requests_expired.get());

        let start = std::time::Instant::now();
        let mut joins = Vec::new();
        for t in 0..4usize {
            let c = coordinator.clone();
            joins.push(std::thread::spawn(move || {
                // expired on arrival: must cost nothing but a counter
                let _ = c.submit(
                    EncodeRequest::new(vec![1, 2, 3])
                        .deadline(Duration::ZERO));
                let mut lat: Vec<Duration> = Vec::new();
                for _round in 0..3 {
                    for s in 0..4 {
                        let len = 200 + 50 * s;
                        let toks: Vec<i32> = (0..len)
                            .map(|i| 3 + ((i * 13 + t * 7 + s) as i32 % 2000))
                            .collect();
                        let t_req = std::time::Instant::now();
                        let rx = c.submit(
                            EncodeRequest::new(toks)
                                .deadline(Duration::from_secs(30)))
                            .unwrap();
                        rx.recv().unwrap().embedding.unwrap();
                        lat.push(t_req.elapsed());
                    }
                }
                lat
            }));
        }
        let mut lat: Vec<Duration> = Vec::new();
        for j in joins {
            lat.extend(j.join().unwrap());
        }
        let wall = start.elapsed();
        lat.sort();
        let pct = |q: f64| lat[((q * (lat.len() - 1) as f64).round()) as usize]
            .as_micros() as f64;
        let hits = m.cache_hits.get() - hits0;
        let lookups = hits + (m.cache_misses.get() - misses0);
        let hit_rate = hits as f64 / lookups.max(1) as f64;
        let expired = m.requests_expired.get() - expired0;
        let rps = lat.len() as f64 / wall.as_secs_f64();
        let mut mtbl = Table::new(&["mixed-deadline serving", "value"]);
        mtbl.row(&["req/s".into(), format!("{rps:.1}")]);
        mtbl.row(&["cache hit rate".into(), format!("{:.0}%", 100.0 * hit_rate)]);
        mtbl.row(&["e2e p50".into(), format!("{:.0}us", pct(0.5))]);
        mtbl.row(&["e2e p99".into(), format!("{:.0}us", pct(0.99))]);
        mtbl.row(&["expired".into(), expired.to_string()]);
        println!("{}", mtbl.render());
        serving.push(("mixed_workers".into(), 4.0));
        serving.push(("mixed_cache_hit_rate".into(), hit_rate));
        serving.push(("mixed_e2e_p50_us".into(), pct(0.5)));
        serving.push(("mixed_e2e_p99_us".into(), pct(0.99)));
        serving.push(("mixed_expired".into(), expired as f64));
        serving.push(("mixed_rps".into(), rps));
    }

    // --- cluster tier (schema v7): router front-end over two loopback
    // replicas — forwarded req/s through the consistent-hash hop, then
    // the same workload replayed against the router's cross-replica
    // cache (hit ≡ recompute bitwise, so the replay is pure routing
    // overhead)
    let mut cluster: Vec<(String, f64)> = Vec::new();
    {
        let mk_replica = || {
            let cfg = ServingConfig {
                variant: Variant::SpectralShift,
                max_batch: 4,
                max_wait_ms: 2,
                queue_capacity: 256,
                cache_capacity: 64,
                ..Default::default()
            };
            let engine = Box::new(CpuEngine::new(CpuModel::new(
                CpuModelConfig::default(), cfg.variant)));
            let c = Arc::new(
                Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
            let (addr, h) = serve(c.clone(), "127.0.0.1:0", 4).unwrap();
            (c, addr, h)
        };
        let (_ra, aaddr, ahandle) = mk_replica();
        let (_rb, baddr, bhandle) = mk_replica();
        let rcfg = ClusterConfig {
            replicas: vec![aaddr.to_string(), baddr.to_string()],
            probe_interval: Duration::from_secs(600),
            cache_capacity: 256,
            ..Default::default()
        };
        let router = Arc::new(ClusterRouter::new(rcfg));
        let (raddr, rhandle) = serve_router(router.clone(), "127.0.0.1:0", 4)
            .expect("bind router");
        let mut client = Client::connect(&raddr).expect("connect router");

        let n_seqs = if smoke() { 4usize } else { 16 };
        let seqs: Vec<Vec<i32>> = (0..n_seqs)
            .map(|s| (0..200 + 20 * s)
                .map(|i| 3 + ((i * 17 + s * 11) as i32 % 2000))
                .collect())
            .collect();

        // cold pass: every request forwarded to a replica
        let start = std::time::Instant::now();
        for (i, t) in seqs.iter().enumerate() {
            assert!(client.encode(i as u64, t).unwrap().starts_with("OK "));
        }
        let fwd_rps = n_seqs as f64 / start.elapsed().as_secs_f64();

        // replay passes: served from the router cache, replicas idle
        let rounds = if smoke() { 2usize } else { 4 };
        let start = std::time::Instant::now();
        for _ in 0..rounds {
            for (i, t) in seqs.iter().enumerate() {
                assert!(client.encode(i as u64, t).unwrap().starts_with("OK "));
            }
        }
        let hit_rps =
            (rounds * n_seqs) as f64 / start.elapsed().as_secs_f64();
        let hits = router.metrics.cache_hits.get();
        let lookups = hits + router.metrics.cache_misses.get();
        let hit_rate = hits as f64 / lookups.max(1) as f64;

        let mut ctbl = Table::new(&["cluster (router + 2 replicas)", "value"]);
        ctbl.row(&["forward req/s".into(), format!("{fwd_rps:.1}")]);
        ctbl.row(&["cache-hit req/s".into(), format!("{hit_rps:.1}")]);
        ctbl.row(&["router hit rate".into(),
                   format!("{:.0}%", 100.0 * hit_rate)]);
        println!("{}", ctbl.render());
        cluster.push(("replicas".into(), 2.0));
        cluster.push(("router_forward_rps".into(), fwd_rps));
        cluster.push(("router_cache_hit_rps".into(), hit_rps));
        cluster.push(("router_cache_hit_rate".into(), hit_rate));
        cluster.push(("forwarded".into(),
                      router.metrics.forwarded.get() as f64));
        cluster.push(("replica_lost".into(),
                      router.metrics.replica_lost.get() as f64));
        rhandle.stop();
        ahandle.stop();
        bhandle.stop();
    }

    // --- streaming long documents (schema v8): chunked ENCODE with the
    // prefix-reuse cache over one loopback replica. The trace shares a
    // 4-chunk template prefix across documents (and is replayed once),
    // so well over half the chunk lookups are reusable — the workload
    // the prefix cache exists for. Embedding cache off to isolate the
    // chunk-granular path.
    let mut longdoc: Vec<(String, f64)> = Vec::new();
    {
        let chunk = if smoke() { 64usize } else { 128 };
        let cfg = ServingConfig {
            variant: Variant::SpectralShift,
            max_batch: 4,
            max_wait_ms: 2,
            queue_capacity: 256,
            seq_buckets: vec![chunk, 2 * chunk],
            workers: 4,
            queue_shards: 2,
            cache_capacity: 0,
            chunk_tokens: chunk,
            prefix_cache_capacity: 256,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig::default(), cfg.variant)));
        let coordinator = Arc::new(
            Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
        let (addr, handle) = serve(coordinator.clone(), "127.0.0.1:0", 4)
            .expect("bind longdoc replica");
        let mut client = Client::connect(&addr).expect("connect longdoc");

        // documents: shared 4-chunk prefix + distinct 2-chunk tail,
        // each 6 chunks = 3× the largest bucket
        let n_docs = if smoke() { 3usize } else { 8 };
        let prefix: Vec<i32> =
            (0..4 * chunk).map(|i| 3 + (i as i32 % 1999)).collect();
        let docs: Vec<Vec<i32>> = (0..n_docs)
            .map(|s| {
                let mut doc = prefix.clone();
                doc.extend((0..2 * chunk)
                    .map(|i| 11 + ((i * 7 + s * 131) as i32 % 1999)));
                doc
            })
            .collect();

        // warm the kernel arenas off the clock with a short (unchunked)
        // request, then snapshot the chunk counters
        assert!(client.encode(0, &docs[0][..chunk]).unwrap()
            .starts_with("OK "));
        let m = &coordinator.metrics;
        let (h0, mi0, ch0) = (m.prefix_hits.get(), m.prefix_misses.get(),
                              m.chunks_computed.get());

        let start = std::time::Instant::now();
        let mut lat: Vec<Duration> = Vec::new();
        for _round in 0..2 {
            // round 0: cold tails, warm shared prefix after the first
            // doc; round 1: full replay, every chunk resident
            for (i, doc) in docs.iter().enumerate() {
                let t_req = std::time::Instant::now();
                assert!(client.encode(i as u64, doc).unwrap()
                    .starts_with("OK "));
                lat.push(t_req.elapsed());
            }
        }
        let wall = start.elapsed();
        let hits = m.prefix_hits.get() - h0;
        let chunk_lookups = hits + (m.prefix_misses.get() - mi0);
        let computed = m.chunks_computed.get() - ch0;
        let hit_rate = hits as f64 / chunk_lookups.max(1) as f64;
        let per_chunk_us =
            wall.as_micros() as f64 / chunk_lookups.max(1) as f64;
        let doc_rps = lat.len() as f64 / wall.as_secs_f64();
        lat.sort();
        let pct = |q: f64| lat[((q * (lat.len() - 1) as f64).round()) as usize]
            .as_micros() as f64;

        let mut ltbl = Table::new(&["long documents (chunked)", "value"]);
        ltbl.row(&["chunk hit rate".into(), format!("{:.0}%", 100.0 * hit_rate)]);
        ltbl.row(&["per-chunk amortized".into(), format!("{per_chunk_us:.0}us")]);
        ltbl.row(&["doc p50".into(), format!("{:.0}us", pct(0.5))]);
        ltbl.row(&["doc p99".into(), format!("{:.0}us", pct(0.99))]);
        ltbl.row(&["docs/s".into(), format!("{doc_rps:.1}")]);
        println!("{}", ltbl.render());
        longdoc.push(("chunk_tokens".into(), chunk as f64));
        longdoc.push(("docs".into(), lat.len() as f64));
        longdoc.push(("chunk_lookups".into(), chunk_lookups as f64));
        longdoc.push(("chunks_computed".into(), computed as f64));
        longdoc.push(("hit_rate".into(), hit_rate));
        longdoc.push(("per_chunk_amortized_us".into(), per_chunk_us));
        longdoc.push(("client_p50_us".into(), pct(0.5)));
        longdoc.push(("client_p99_us".into(), pct(0.99)));
        longdoc.push(("doc_rps".into(), doc_rps));
        handle.stop();
    }

    let json = render_json(threads, c, d, &entries, &speedups, &serving,
                           &isa_rows, &quant, &cluster, &longdoc);
    // benches run with cwd = rust/; the repo root is one level up
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_kernels.json"
    } else {
        "BENCH_kernels.json"
    };
    std::fs::write(path, json).expect("write BENCH_kernels.json");
    println!("wrote {path}");
}

fn push(entries: &mut Vec<Entry>, table: &mut Table, name: &str, n: usize,
        s: &ssaformer::benchkit::Stats, flops: f64, threads: usize) {
    let secs = s.median.as_secs_f64();
    entries.push(Entry {
        name: name.to_string(),
        n,
        ns_per_op: secs * 1e9,
        gflops: flops / secs / 1e9,
        threads,
    });
    table.row(&[name.to_string(), n.to_string(), fmt_duration(s.median),
                format!("{:.2}", flops / secs / 1e9), threads.to_string()]);
}

#[allow(clippy::too_many_arguments)]
fn render_json(threads: usize, c: usize, d: usize, entries: &[Entry],
               speedups: &[(String, f64)],
               serving: &[(String, f64)],
               isa_rows: &[(String, f64)],
               quant: &[(String, f64)],
               cluster: &[(String, f64)],
               longdoc: &[(String, f64)]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"ssaformer/bench_kernels/v9\",\n");
    out.push_str("  \"generated_by\": \"cargo bench --bench bench_snapshot\",\n");
    out.push_str(&format!("  \"smoke\": {},\n", smoke()));
    out.push_str(&format!("  \"threads\": {threads},\n"));
    out.push_str(&format!("  \"c\": {c},\n"));
    out.push_str(&format!("  \"d\": {d},\n"));
    out.push_str(&format!("  \"kernel_active\": \"{}\",\n",
                          active_isa().token()));
    out.push_str(&format!(
        "  \"kernel_available\": [{}],\n",
        Isa::available().iter().map(|i| format!("\"{}\"", i.token()))
            .collect::<Vec<_>>().join(", ")));
    out.push_str("  \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let comma = if i + 1 < entries.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"n\": {}, \"ns_per_op\": {:.1}, \
             \"gflops\": {:.3}, \"threads\": {}}}{comma}\n",
            e.name, e.n, e.ns_per_op, e.gflops, e.threads));
    }
    out.push_str("  ],\n");
    out.push_str("  \"speedup\": {\n");
    for (i, (name, x)) in speedups.iter().enumerate() {
        let comma = if i + 1 < speedups.len() { "," } else { "" };
        out.push_str(&format!("    \"{name}\": {x:.3}{comma}\n"));
    }
    out.push_str("  },\n");
    // serving-path trajectory: requests/sec through the CPU backend
    out.push_str("  \"serving\": {\n");
    for (i, (name, x)) in serving.iter().enumerate() {
        let comma = if i + 1 < serving.len() { "," } else { "" };
        out.push_str(&format!("    \"{name}\": {x:.2}{comma}\n"));
    }
    out.push_str("  },\n");
    // per-ISA dispatch rows (v6): gemm GF/s and layers=1 serving rps
    // with the kernel core pinned to each arm this host can run
    out.push_str("  \"isa\": {\n");
    for (i, (name, x)) in isa_rows.iter().enumerate() {
        let comma = if i + 1 < isa_rows.len() { "," } else { "" };
        out.push_str(&format!("    \"{name}\": {x:.3}{comma}\n"));
    }
    out.push_str("  },\n");
    // precision-tier rows (v9): quantized GEMM GF/s per tier and
    // layers=1 serving rps with the admission knob forcing each tier
    out.push_str("  \"quant\": {\n");
    for (i, (name, x)) in quant.iter().enumerate() {
        let comma = if i + 1 < quant.len() { "," } else { "" };
        out.push_str(&format!("    \"{name}\": {x:.3}{comma}\n"));
    }
    out.push_str("  },\n");
    // cluster-tier rows (v7): router front-end over loopback replicas
    out.push_str("  \"cluster\": {\n");
    for (i, (name, x)) in cluster.iter().enumerate() {
        let comma = if i + 1 < cluster.len() { "," } else { "" };
        out.push_str(&format!("    \"{name}\": {x:.3}{comma}\n"));
    }
    out.push_str("  },\n");
    // long-document rows (v8): chunked ENCODE + prefix-reuse cache
    // over a high-prefix-overlap trace
    out.push_str("  \"longdoc\": {\n");
    for (i, (name, x)) in longdoc.iter().enumerate() {
        let comma = if i + 1 < longdoc.len() { "," } else { "" };
        out.push_str(&format!("    \"{name}\": {x:.3}{comma}\n"));
    }
    out.push_str("  }\n");
    out.push_str("}\n");
    out
}
