//! E3 — Figure 2: spectrum analysis of the self-attention matrix (top
//! panel) vs the spectral-shifting approximation (bottom panel).
//!
//! The paper plots cumulative eigenvalue mass vs eigenvalue index and
//! argues the approximation "has no long tail so it is not a low rank
//! matrix". We regenerate both series on two matrix sources:
//!   (a) synthetic Gaussian q,k (seed-controlled),
//!   (b) q,k with slow/fast spectral decay via controlled mixing,
//! and for both the Nystrom baseline (rank-c cliff) and SS (δ floor).
//!
//! Run: cargo bench --bench figure2_spectrum

use ssaformer::attention::full::attention_matrix;
use ssaformer::attention::spectral_shift::{
    nystrom_matrix_exact, spectral_shift_matrix_exact, MiddleForm,
};
use ssaformer::attention::Tensor2;
use ssaformer::benchkit::{banner, Table};
use ssaformer::rngx::Rng;
use ssaformer::spectral::Spectrum;

/// q,k whose Gram spectrum decays like i^-alpha: mix a few strong
/// directions into Gaussian noise.
fn decaying_qk(rng: &mut Rng, n: usize, d: usize, alpha: f64)
               -> (Tensor2, Tensor2) {
    let mut q = Tensor2::randn(rng, n, d, 0.3);
    let mut k = Tensor2::randn(rng, n, d, 0.3);
    // add r dominant rank-1 components with decaying weights
    let r = d / 2;
    for comp in 0..r {
        let w = ((comp + 1) as f64).powf(-alpha) as f32 * 3.0;
        let dir: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let coef_q: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let coef_k: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        for i in 0..n {
            for j in 0..d {
                q.data[i * d + j] += w * coef_q[i] * dir[j];
                k.data[i * d + j] += w * coef_k[i] * dir[j];
            }
        }
    }
    (q, k)
}

fn report(tag: &str, q: &Tensor2, k: &Tensor2, c: usize, rank_rtol: f64) {
    let n = q.rows;
    let s_true = attention_matrix(q, k, None);
    let s_ny = nystrom_matrix_exact(q, k, c, None);
    let (s_ss, delta) = spectral_shift_matrix_exact(
        q, k, c, rank_rtol, MiddleForm::Eq8, true, None);
    let sp_true = Spectrum::of(&s_true);
    let sp_ny = Spectrum::of(&s_ny);
    let sp_ss = Spectrum::of(&s_ss);

    banner(&format!("Figure 2 [{tag}] (n={n}, c={c}, rank_rtol={rank_rtol})"),
           &format!("fitted δ = {delta:.5}; series: cumulative |eig| mass"));
    let mut t = Table::new(&["idx", "cum true S", "cum Nystrom", "cum SS"]);
    for i in (0..n).step_by((n / 12).max(1)) {
        t.row(&[
            format!("{}", i + 1),
            format!("{:.4}", sp_true.cumulative[i]),
            format!("{:.4}", sp_ny.cumulative[i]),
            format!("{:.4}", sp_ss.cumulative[i]),
        ]);
    }
    println!("{}", t.render());
    let mut s = Table::new(&["statistic", "true", "nystrom", "ss"]);
    s.row(&["effective rank".into(),
            format!("{:.1}", sp_true.effective_rank()),
            format!("{:.1}", sp_ny.effective_rank()),
            format!("{:.1}", sp_ss.effective_rank())]);
    s.row(&["near-zero eigs (<1e-8)".into(),
            format!("{}", sp_true.near_zero_count(1e-8)),
            format!("{}", sp_ny.near_zero_count(1e-8)),
            format!("{}", sp_ss.near_zero_count(1e-8))]);
    s.row(&["idx reaching 99% mass".into(),
            format!("{}", sp_true.index_reaching(0.99)),
            format!("{}", sp_ny.index_reaching(0.99)),
            format!("{}", sp_ss.index_reaching(0.99))]);
    println!("{}", s.render());
}

fn main() {
    let mut rng = Rng::new(0);
    let (n, d, c) = (256, 64, 32);

    // (a) plain Gaussian q,k
    let q = Tensor2::randn(&mut rng, n, d, 1.0);
    let k = Tensor2::randn(&mut rng, n, d, 1.0);
    report("gaussian q,k", &q, &k, c, 0.05);

    // (b) slow spectral decay — the regime the paper targets
    let (qs, ks) = decaying_qk(&mut rng, n, d, 0.3);
    report("slow-decay q,k (α=0.3)", &qs, &ks, c, 0.05);

    // (c) fast decay — Nystrom should suffice here (control)
    let (qf, kf) = decaying_qk(&mut rng, n, d, 1.5);
    report("fast-decay q,k (α=1.5)", &qf, &kf, c, 0.05);

    println!("Paper claim check: in every panel the Nystrom column shows \
              ≥ n−c near-zero\neigenvalues (a hard rank cliff) while the SS \
              column keeps full support —\nFigure 2's 'no long tail' \
              statement, made precise.\n");
}
