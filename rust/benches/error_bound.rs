//! E5 — sec 7 error bound (eq 12): empirical E vs the bound's RHS.
//!
//!   E ≤ 1 + ‖A⁺‖∞ (1 + δ‖A⁺‖∞)(1 − ‖A⁺ − Z*‖∞)
//!
//! measured with E = ‖S − S̃‖∞ (max row abs sum, the norm used in the
//! proof chain). We sweep landmark count and q/k scale (which controls
//! the conditioning of A_s) and report E, the RHS, and the slack.
//!
//! Run: cargo bench --bench error_bound

use ssaformer::attention::full::attention_matrix;
use ssaformer::attention::spectral_shift::{
    segment_means_f64, spectral_shift_matrix_exact, MiddleForm,
};
use ssaformer::attention::Tensor2;
use ssaformer::benchkit::{banner, Table};
use ssaformer::linalg::{self, norms};
use ssaformer::rngx::Rng;

fn main() {
    banner("E5 — eq 12 error bound: empirical E vs bound RHS",
           "E = ‖S − S̃‖∞; Z* = 20-iteration eq-11 pseudoinverse;\n\
            bound RHS = 1 + ‖A⁺‖∞(1 + δ‖A⁺‖∞)(1 − ‖A⁺ − Z*‖∞)");

    let n = 192;
    let d = 32;
    let mut t = Table::new(&["c", "qk scale", "E (measured)", "bound RHS",
                             "holds", "‖A⁺‖∞", "δ"]);
    for &c in &[12usize, 24, 48] {
        for &scale in &[0.5f32, 1.0, 2.0] {
            let mut rng = Rng::new((c * 17) as u64 + scale as u64);
            let q = Tensor2::randn(&mut rng, n, d, scale);
            let k = Tensor2::randn(&mut rng, n, d, scale);
            let s_true = attention_matrix(&q, &k, None);
            let (s_apx, delta) = spectral_shift_matrix_exact(
                &q, &k, c, 1e-6, MiddleForm::Eq8, true, None);
            let e = norms::inf(&s_true.sub(&s_apx));

            // bound ingredients on the landmark block
            let qm = q.to_matrix();
            let km = k.to_matrix();
            let att_scale = 1.0 / (d as f64).sqrt();
            let qt = segment_means_f64(&qm, c);
            let kt = segment_means_f64(&km, c);
            let a = linalg::row_softmax(
                &linalg::matmul(&qt, &kt.transpose()).scale(att_scale));
            let apinv = linalg::pinv(&a, 1e-10);
            let z = linalg::ns_pinv_ord7(&a, 20);
            let napx = norms::inf(&apinv);
            let nzdiff = norms::inf(&apinv.sub(&z));
            let rhs = 1.0 + napx * (1.0 + delta * napx) * (1.0 - nzdiff).max(0.0);
            // eq 12's derivation assumes Z* satisfies ||A+ - Z*|| < 1
            // (the iterative pinv has converged); when the landmark
            // block is too ill-conditioned for 20 iterations the bound
            // is vacuous, not violated.
            let verdict = if nzdiff >= 1.0 {
                "precond-unmet".to_string()
            } else if e <= rhs {
                "yes".into()
            } else {
                "VIOLATED".to_string()
            };
            t.row(&[
                c.to_string(),
                format!("{scale}"),
                format!("{e:.4}"),
                format!("{rhs:.2}"),
                verdict,
                format!("{napx:.1}"),
                format!("{delta:.4}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("reading: wherever the eq-12 precondition ‖A⁺−Z*‖<1 holds, \
              the bound holds\nbut is loose (RHS ≈ ‖A⁺‖∞ ≫ E) — it is a \
              triangle-inequality bound over three\nrow-softmax factors. \
              Rows marked precond-unmet have landmark blocks too\n\
              ill-conditioned for the 20-iteration Z* (bound vacuous \
              there).\n");

    // E decreases with c at fixed scale — the actionable content
    banner("E5b — E vs landmark count (scale=1.0)", "");
    let mut t = Table::new(&["c", "E", "E/‖S‖∞"]);
    let mut rng = Rng::new(5);
    let q = Tensor2::randn(&mut rng, n, d, 1.0);
    let k = Tensor2::randn(&mut rng, n, d, 1.0);
    let s_true = attention_matrix(&q, &k, None);
    for &c in &[6usize, 12, 24, 48, 96] {
        // rank_rtol 1e-3 regularizes the pinv: with 1e-6 an
        // ill-conditioned A_s at some c inflates A+ and the error
        // explodes non-monotonically (documented in E9d)
        let (s_apx, _) = spectral_shift_matrix_exact(
            &q, &k, c, 1e-3, MiddleForm::Eq8, true, None);
        let e = norms::inf(&s_true.sub(&s_apx));
        t.row(&[c.to_string(), format!("{e:.4}"),
                format!("{:.4}", e / norms::inf(&s_true))]);
    }
    println!("{}", t.render());
}
