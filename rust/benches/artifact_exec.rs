//! §Perf probe: raw execution time of every encode artifact
//! (variant × seq), excluding batching/queueing — the L1/L2 hot-path
//! metric the optimization pass iterates on.
//!
//! Run: cargo bench --bench artifact_exec

use ssaformer::benchkit::{banner, bench, fmt_duration, Table};
use ssaformer::config::Variant;
use ssaformer::runtime::{ArtifactKind, Engine};
use std::time::Duration;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP artifact_exec: artifacts/ not built");
        return;
    }
    banner("perf probe — encode artifact execution time",
           "batch=4, params resident on device; median of repeated runs");
    let engine = Engine::new("artifacts").expect("engine");
    let params_host = engine.init_params().unwrap();
    let params = engine
        .buffer_f32(&params_host, &[params_host.len()])
        .unwrap();

    let mut t = Table::new(&["variant", "n=128", "n=256", "n=512", "n=1024"]);
    for variant in [Variant::Full, Variant::Nystrom, Variant::SpectralShift] {
        let mut row = vec![variant.token().to_string()];
        for seq in [128usize, 256, 512, 1024] {
            match engine.load(ArtifactKind::Encode, variant, seq) {
                Ok(model) => {
                    let b = model.entry.batch;
                    let tokens: Vec<i32> =
                        (0..b * seq).map(|i| 3 + (i as i32 % 2000)).collect();
                    // warmup
                    let _ = model.encode(&engine, &params, &tokens).unwrap();
                    let s = bench(
                        || {
                            std::hint::black_box(
                                model.encode(&engine, &params, &tokens).unwrap());
                        },
                        Duration::from_secs(2),
                        7,
                    );
                    row.push(fmt_duration(s.median));
                }
                Err(_) => row.push("-".into()),
            }
        }
        t.row(&row);
    }
    println!("{}", t.render());
}
