//! E4 — Lemma 1 / Theorem 1: approximation error of the three SPSD
//! models (prototype/Nystrom, full SS, modified SS) across matrix
//! families, landmark counts, and tail levels.
//!
//! The paper's claim: modified spectral shifting has a "much stronger
//! error bound than the Nystrom method", exactly recovering matrices
//! with k spikes + flat tail from c = O(k) columns (Lemma 1), at O(c³)
//! fitting cost vs the full model's O(n²c) (sec 3 vs sec 4).
//!
//! Run: cargo bench --bench approx_error

use ssaformer::benchkit::{banner, bench, fmt_duration, Table};
use ssaformer::rngx::Rng;
use ssaformer::spsd::*;
use std::time::Duration;

fn crate_matrix_randn(rng: &mut Rng, rows: usize, cols: usize)
                      -> ssaformer::linalg::Matrix {
    ssaformer::linalg::Matrix::from_fn(rows, cols, |_, _| rng.normal())
}

fn main() {
    banner("E4a — spiked spectrum: error vs tail level θ (n=96, k=5, c=16)",
           "modified SS fitted on the shifted matrix (Lemma 1 config);\n\
            errors are relative Frobenius.");
    let mut t = Table::new(&["theta", "Nystrom", "full SS", "modified SS",
                             "mss delta"]);
    let n = 96;
    for &theta in &[0.05, 0.2, 0.5, 1.0] {
        let mut rng = Rng::new(42);
        let k = spiked_spsd(&mut rng, n, 5, 6.0, 4.0, theta);
        let cols = sample_columns(&mut rng, n, 16, ColumnSampling::UniformRandom);
        let ny = prototype_model(&k, &cols);
        let fss = full_ss_model(&k, &cols, 1e-10);
        let mss = modified_ss_model_shifted(&k, &cols, theta, 1e-8);
        t.row(&[
            format!("{theta}"),
            format!("{:.2e}", rel_fro_error(&k, &ny.approx)),
            format!("{:.2e}", rel_fro_error(&k, &fss.approx)),
            format!("{:.2e}", rel_fro_error(&k, &mss.approx)),
            format!("{:.3}", mss.delta),
        ]);
    }
    println!("{}", t.render());
    println!("shape check: Nystrom error grows ∝ θ (it cannot represent the \
              tail);\nmodified SS stays at numerical zero — Lemma 1.\n");

    banner("E4b — error vs landmark count c (spiked, θ=0.4)", "");
    let mut t = Table::new(&["c", "Nystrom", "modified SS", "exact from c≥k?"]);
    for &c in &[4usize, 6, 8, 16, 32] {
        let mut rng = Rng::new(7);
        let k = spiked_spsd(&mut rng, n, 5, 6.0, 4.0, 0.4);
        let cols = sample_columns(&mut rng, n, c, ColumnSampling::UniformRandom);
        let ny = prototype_model(&k, &cols);
        let mss = modified_ss_model_shifted(&k, &cols, 0.4, 1e-8);
        let e = rel_fro_error(&k, &mss.approx);
        t.row(&[
            c.to_string(),
            format!("{:.2e}", rel_fro_error(&k, &ny.approx)),
            format!("{:.2e}", e),
            if c >= 5 { format!("yes ({e:.1e})") } else { "no (c<k)".into() },
        ]);
    }
    println!("{}", t.render());

    banner("E4c-i — noisy flat tail: spikes + θ(1±25%) tail (n=96, c=16)",
           "the realistic version of Lemma 1's spectrum; SS wins, not \
            exactly zero");
    let mut t = Table::new(&["theta", "Nystrom", "modified SS", "ss delta"]);
    for &theta in &[0.1, 0.3, 0.6] {
        let mut rng = Rng::new(13);
        // flat tail perturbed ±25%: build spiked then jitter eigenvalues
        // by adding a small random SPSD correction of norm 0.25θ
        let k0 = spiked_spsd(&mut rng, n, 5, 6.0, 4.0, theta);
        let jit = {
            let b = crate_matrix_randn(&mut rng, n, n);
            let g = ssaformer::linalg::gram(&b); // PSD
            let s = ssaformer::linalg::norms::spectral(&g, 40);
            g.scale(0.25 * theta / s)
        };
        let k = k0.add(&jit);
        let cols = sample_columns(&mut rng, n, 16, ColumnSampling::UniformRandom);
        let ny = prototype_model(&k, &cols);
        let mss = modified_ss_model_shifted(&k, &cols, theta, 1e-3);
        t.row(&[
            format!("{theta}"),
            format!("{:.3}", rel_fro_error(&k, &ny.approx)),
            format!("{:.3}", rel_fro_error(&k, &mss.approx)),
            format!("{:.3}", mss.delta),
        ]);
    }
    println!("{}", t.render());

    banner("E4c-ii — power-law spectra (NEGATIVE control)",
           "λ_i = i^-decay has no flat tail, so the δI term cannot model \
            it;\nmodified SS ≈ Nystrom (or slightly worse when δ \
            misfires). The paper's\nadvantage requires a near-flat \
            discarded tail — documented in DESIGN.md.");
    let mut t = Table::new(&["decay", "Nystrom", "modified SS", "ss delta"]);
    for &decay in &[0.25, 0.5, 1.0, 2.0] {
        let mut rng = Rng::new(3);
        let k = power_law_spsd(&mut rng, n, decay);
        let cols = sample_columns(&mut rng, n, 16, ColumnSampling::Strided);
        let ny = prototype_model(&k, &cols);
        let mss = modified_ss_model(&k, &cols, 0.3);
        t.row(&[
            format!("{decay}"),
            format!("{:.3}", rel_fro_error(&k, &ny.approx)),
            format!("{:.3}", rel_fro_error(&k, &mss.approx)),
            format!("{:.4}", mss.delta),
        ]);
    }
    println!("{}", t.render());

    banner("E4d — fitting cost: modified O(c³) vs full O(n²c) (sec 3 vs 4)",
           "wall-clock of the model fit, n=192, c=24");
    let mut rng = Rng::new(9);
    let k = spiked_spsd(&mut rng, 192, 5, 6.0, 4.0, 0.3);
    let cols = sample_columns(&mut rng, 192, 24, ColumnSampling::UniformRandom);
    let budget = Duration::from_millis(400);
    let mut t = Table::new(&["model", "fit+reconstruct time"]);
    let s_full = bench(|| { std::hint::black_box(
        full_ss_model(&k, &cols, 1e-10)); }, budget, 12);
    let s_mod = bench(|| { std::hint::black_box(
        modified_ss_model(&k, &cols, 1e-8)); }, budget, 12);
    let s_ny = bench(|| { std::hint::black_box(
        prototype_model(&k, &cols)); }, budget, 12);
    t.row(&["prototype (Nystrom)".into(), fmt_duration(s_ny.median)]);
    t.row(&["full SS (sec 3)".into(), fmt_duration(s_full.median)]);
    t.row(&["modified SS (sec 4)".into(), fmt_duration(s_mod.median)]);
    t.row(&["full/modified ratio".into(), format!(
        "{:.1}x", s_full.median.as_secs_f64() / s_mod.median.as_secs_f64())]);
    println!("{}", t.render());
}
