//! E8 — sec 9 deployment claim: end-to-end serving latency/throughput
//! of the full L3 stack (router → batcher → PJRT encode artifact) for
//! the exact, Nystromformer and spectral-shifting variants.
//!
//! Needs `make artifacts`. For each variant, replays the same Poisson
//! trace through a fresh coordinator and reports throughput, mean/p99
//! e2e latency, queue latency, execution latency, and coordinator
//! overhead (e2e − exec − queue).
//!
//! Run: cargo bench --bench serving_throughput

use ssaformer::benchkit::{banner, Table};
use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{Coordinator, ExecBackend};
use ssaformer::runtime::Engine;
use ssaformer::workload::{generate_trace, LengthDist, TraceConfig};
use std::sync::Arc;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP serving_throughput: artifacts/ not built");
        return;
    }
    banner("E8 — serving throughput/latency per attention variant",
           "trace: 48 requests, Poisson λ=30/s, zipf lengths over \
            {128,256,512};\nbatch≤4, max-wait 10ms; same trace for every \
            variant.");

    let trace = generate_trace(&TraceConfig {
        rate: 30.0,
        count: 48,
        lengths: LengthDist::ZipfBuckets(1.1),
        buckets: vec![128, 256, 512],
        vocab: 2048,
        seed: 11,
    });

    let mut t = Table::new(&["variant", "warmup", "wall", "req/s",
                             "e2e p50", "e2e p99", "exec mean",
                             "queue mean", "batches"]);
    for variant in [Variant::Full, Variant::Nystrom, Variant::SpectralShift] {
        let engine = Arc::new(Engine::new("artifacts").expect("engine"));
        let cfg = ServingConfig {
            variant,
            max_batch: 4,
            max_wait_ms: 10,
            queue_capacity: 128,
            // pool of 2 over 2 shards; cache off so every request pays
            // the encode cost the bench is comparing across variants
            workers: 2,
            queue_shards: 2,
            cache_capacity: 0,
            ..Default::default()
        };
        let t_warm = std::time::Instant::now();
        let coordinator = Arc::new(Coordinator::start(ExecBackend::Xla(engine), &cfg).unwrap());
        let warmup = t_warm.elapsed();

        let start = std::time::Instant::now();
        // replay with arrival pacing from 3 threads
        let mut joins = Vec::new();
        for chunk in trace.chunks(16) {
            let chunk: Vec<_> = chunk.to_vec();
            let c = coordinator.clone();
            joins.push(std::thread::spawn(move || {
                let t0 = std::time::Instant::now();
                for req in &chunk {
                    let now = t0.elapsed();
                    if req.arrival > now {
                        std::thread::sleep(req.arrival - now);
                    }
                    let resp = c.submit_blocking(req.tokens.clone()).unwrap();
                    assert!(resp.embedding.is_ok());
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = start.elapsed();
        let m = &coordinator.metrics;
        t.row(&[
            variant.token().to_string(),
            format!("{:.1}s", warmup.as_secs_f64()),
            format!("{:.2}s", wall.as_secs_f64()),
            format!("{:.1}", m.requests_done.get() as f64 / wall.as_secs_f64()),
            format!("{}ms", m.e2e_latency.quantile_us(0.5) / 1000),
            format!("{}ms", m.e2e_latency.quantile_us(0.99) / 1000),
            format!("{:.0}ms", m.exec_latency.mean_us() / 1000.0),
            format!("{:.0}ms", m.queue_latency.mean_us() / 1000.0),
            m.batches_executed.get().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("shape check (paper sec 9): ss/nystrom execute faster than \
              full at the\nlonger buckets; the gap widens with sequence \
              length (see table1 bench for\nthe kernel-level scaling).\n");

    // single-bucket saturated-load comparison at the longest bucket
    banner("E8b — saturated offered load per bucket (crossover check)",
           "24 back-to-back requests per cell, batch 4 — isolates encode \
            cost.\nAlso reports coordinator overhead = e2e − exec − queue \
            (the L3 §Perf target).");
    let mut t = Table::new(&["variant", "bucket", "total", "req/s",
                             "exec mean", "coord overhead"]);
    for &(len, bucket) in &[(500usize, 512usize), (1000, 1024)] {
        for variant in [Variant::Full, Variant::Nystrom, Variant::SpectralShift] {
            let engine = Arc::new(Engine::new("artifacts").expect("engine"));
            let cfg = ServingConfig {
                variant,
                max_batch: 4,
                max_wait_ms: 2,
                queue_capacity: 128,
                workers: 2,
                queue_shards: 2,
                cache_capacity: 0,
                ..Default::default()
            };
            let coordinator = Arc::new(Coordinator::start(ExecBackend::Xla(engine), &cfg).unwrap());
            let toks: Vec<i32> = (0..len).map(|i| 3 + (i as i32 % 2000)).collect();
            let start = std::time::Instant::now();
            let rxs: Vec<_> = (0..24)
                .map(|_| coordinator.submit(toks.clone()).unwrap())
                .collect();
            for rx in rxs {
                assert!(rx.recv().unwrap().embedding.is_ok());
            }
            let wall = start.elapsed();
            let m = &coordinator.metrics;
            // per-request coordinator overhead: e2e minus the time the
            // request spent waiting for or inside the executor
            let overhead_us = (m.e2e_latency.mean_us()
                - m.exec_latency.mean_us()
                - m.queue_latency.mean_us()).max(0.0);
            t.row(&[
                variant.token().to_string(),
                bucket.to_string(),
                format!("{:.2}s", wall.as_secs_f64()),
                format!("{:.1}", 24.0 / wall.as_secs_f64()),
                format!("{:.0}ms", m.exec_latency.mean_us() / 1000.0),
                format!("{:.1}ms ({:.1}%)", overhead_us / 1000.0,
                        100.0 * overhead_us / m.e2e_latency.mean_us().max(1.0)),
            ]);
        }
    }
    println!("{}", t.render());
}
