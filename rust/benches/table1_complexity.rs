//! E1 — Table 1: measured complexity scaling of every attention variant.
//!
//! The paper's Table 1 cites asymptotic classes; this bench regenerates
//! it empirically: wall-clock per call vs sequence length n, plus the
//! fitted log-log scaling exponent per method. Expected shape:
//!   Transformer ≈ 2.0, Sparse ≈ 1.5, LSH(Reformer) ≈ 1+, Linformer /
//!   Nystromformer / Spectral Shifting ≈ 1.0.
//!
//! Also prints the E7 (sec-8) component-cost breakdown for the SS path.
//!
//! Run: cargo bench --bench table1_complexity

use ssaformer::attention::*;
use ssaformer::benchkit::{banner, bench, fmt_duration, scaling_exponent, Table};
use ssaformer::rngx::Rng;
use std::time::Duration;

fn main() {
    banner("Table 1 — complexity of attention variants (measured)",
           "wall-clock per attention call, d=64, c=64 landmarks, f32.\n\
            Rightmost column: fitted exponent b in time ∝ n^b.");

    let sizes = [256usize, 512, 1024, 2048, 4096];
    let d = 64;
    let c = 64;
    let budget = Duration::from_millis(300);

    type AttnFn<'a> = Box<dyn Fn(&Tensor2, &Tensor2, &Tensor2) -> Tensor2 + 'a>;
    let variants: Vec<(&str, &str, AttnFn)> = vec![
        ("Transformer (exact)", "O(n^2)",
         Box::new(|q: &Tensor2, k: &Tensor2, v: &Tensor2| softmax_attention(q, k, v, None))),
        ("Sparse Transformer", "O(n*sqrt n)",
         Box::new(|q: &Tensor2, k: &Tensor2, v: &Tensor2| sparse_attention(q, k, v, None, None, None))),
        ("Reformer (LSH)", "O(n log n)",
         Box::new(|q: &Tensor2, k: &Tensor2, v: &Tensor2| lsh_attention(q, k, v, 2, None, 7, None))),
        ("Linformer", "O(n)",
         Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| linformer_attention(q, k, v, c, 7, None))),
        ("Nystromformer", "O(n)",
         Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| nystrom_attention(q, k, v, c, 8, None))),
        ("Spectral Shifting", "O(n)",
         Box::new(move |q: &Tensor2, k: &Tensor2, v: &Tensor2| {
             spectral_shift_attention(q, k, v, &SpectralShiftConfig::new(c))
         })),
    ];

    let mut headers: Vec<String> = vec!["variant".into(), "paper".into()];
    headers.extend(sizes.iter().map(|n| format!("n={n}")));
    headers.push("fit n^b".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    for (name, paper, f) in &variants {
        let mut times = Vec::new();
        let mut row = vec![name.to_string(), paper.to_string()];
        for &n in &sizes {
            let mut rng = Rng::new(n as u64);
            let q = Tensor2::randn(&mut rng, n, d, 1.0);
            let k = Tensor2::randn(&mut rng, n, d, 1.0);
            let v = Tensor2::randn(&mut rng, n, d, 1.0);
            let stats = bench(|| { std::hint::black_box(f(&q, &k, &v)); },
                              budget, 30);
            times.push(stats.median.as_secs_f64());
            row.push(fmt_duration(stats.median));
        }
        let xs: Vec<f64> = sizes.iter().map(|&n| n as f64).collect();
        table.row(&{
            let mut r = row.clone();
            r.push(format!("{:.2}", scaling_exponent(&xs, &times)));
            r
        });
    }
    println!("{}", table.render());

    // ---- E7 / sec 8: component breakdown of the SS path at n=4096 ----
    banner("sec 8 — component cost breakdown (spectral shifting, n=4096)",
           "predicted: landmarks O(n), factors O(nc(d+dv)), pinv O(c^3), \
            combine O(ncd)");
    let n = 4096;
    let mut rng = Rng::new(1);
    let q = Tensor2::randn(&mut rng, n, d, 1.0);
    let k = Tensor2::randn(&mut rng, n, d, 1.0);
    let v = Tensor2::randn(&mut rng, n, d, 1.0);
    let mut t = Table::new(&["component", "median"]);
    let s = bench(|| { std::hint::black_box(segment_means(&q, c)); },
                  budget, 50);
    t.row(&["segment-means landmarks".into(), fmt_duration(s.median)]);
    let nys = bench(|| {
        std::hint::black_box(nystrom_attention(&q, &k, &v, c, 8, None));
    }, budget, 20);
    let full_ss = bench(|| {
        std::hint::black_box(spectral_shift_attention(
            &q, &k, &v, &SpectralShiftConfig::new(c)));
    }, budget, 20);
    t.row(&["nystrom total".into(), fmt_duration(nys.median)]);
    t.row(&["spectral shift total".into(), fmt_duration(full_ss.median)]);
    t.row(&["SS overhead vs nystrom".into(), format!(
        "{:.1}%",
        100.0 * (full_ss.median.as_secs_f64() / nys.median.as_secs_f64() - 1.0))]);
    println!("{}", t.render());
}
