//! Checkpoint acceptance tests (the loadable-weights contract):
//!
//! 1. `save → load` round-trips the encoder weights **bitwise**, and a
//!    coordinator serving the loaded checkpoint returns embeddings
//!    bitwise-equal to the stack that wrote it.
//! 2. Malformed files — truncated, corrupt header, wrong dims, trailing
//!    bytes — fail closed with typed [`CheckpointError`]s; serving with
//!    `init = load` on a bad file never starts.
//! 3. The `weights`/`init` knobs thread end to end through
//!    `ServingConfig` → `ExecBackend::auto` → `Coordinator`.

use ssaformer::config::{InitPolicy, ServingConfig, Variant};
use ssaformer::coordinator::{
    Coordinator, CpuModel, CpuModelConfig, ExecBackend,
};
use ssaformer::model::checkpoint::{self, CheckpointError};
use ssaformer::runtime::RuntimeError;
use std::path::PathBuf;
use std::sync::Arc;

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 23 + seed) % 2000)).collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "ssaformer-it-ckpt-{}-{name}.bin", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn saved_weights_serve_bitwise_through_the_coordinator() {
    // write a projected depth-3 model's weights ...
    let mcfg = CpuModelConfig {
        layers: 3, ffn_mult: 2, projections: true, ..Default::default()
    };
    let donor = CpuModel::new(mcfg, Variant::SpectralShift);
    let path = tmp("serve");
    checkpoint::save(donor.stack(), &path).unwrap();

    // ... then serve twice: seeded (the donor's config) vs loaded
    let serve = |weights: Option<String>| -> Vec<Vec<f32>> {
        let cfg = ServingConfig {
            artifacts_dir: "no/such/artifacts".into(),
            variant: Variant::SpectralShift,
            layers: 3,
            ffn_mult: 2,
            projections: true,
            init: if weights.is_some() { InitPolicy::Load }
                  else { InitPolicy::Seeded },
            weights,
            max_batch: 2,
            max_wait_ms: 2,
            queue_capacity: 32,
            workers: 1,
            cache_capacity: 0,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let backend = ExecBackend::auto(&cfg).unwrap();
        let c = Arc::new(Coordinator::start(backend, &cfg).unwrap());
        (0..3)
            .map(|i| {
                c.submit_blocking(toks(60 + 30 * i, i as i32))
                    .unwrap().embedding.unwrap()
            })
            .collect()
    };
    let seeded = serve(None);
    let loaded = serve(Some(path.to_string_lossy().into_owned()));
    for (i, (a, b)) in seeded.iter().zip(&loaded).enumerate() {
        assert_eq!(bits(a), bits(b),
                   "req {i}: loaded checkpoint must serve the saved \
                    function bitwise");
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn roundtrip_is_bitwise_stable_across_a_second_save() {
    // save(load(save(m))) must produce byte-identical files — the
    // strongest cheap statement of lossless serialization
    let mcfg = CpuModelConfig {
        layers: 4, ffn_mult: 2, projections: true, ..Default::default()
    };
    let m = CpuModel::new(mcfg, Variant::Nystrom);
    let p1 = tmp("rt1");
    let p2 = tmp("rt2");
    checkpoint::save(m.stack(), &p1).unwrap();
    let ck = checkpoint::load(&p1).unwrap();
    let stack = ck.into_stack(m.stack().variants().to_vec()).unwrap();
    checkpoint::save(&stack, &p2).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap(),
               "re-serialization must be byte-identical");
    std::fs::remove_file(&p1).unwrap();
    std::fs::remove_file(&p2).unwrap();
}

#[test]
fn malformed_checkpoints_fail_closed_end_to_end() {
    let mcfg = CpuModelConfig { layers: 2, ..Default::default() };
    let donor = CpuModel::new(mcfg, Variant::SpectralShift);
    let path = tmp("mal");
    checkpoint::save(donor.stack(), &path).unwrap();
    let good = std::fs::read(&path).unwrap();

    let cfg_for = |p: &PathBuf| ServingConfig {
        artifacts_dir: "no/such/artifacts".into(),
        layers: 2,
        weights: Some(p.to_string_lossy().into_owned()),
        init: InitPolicy::Load,
        ..Default::default()
    };

    // typed errors at the parser ...
    std::fs::write(&path, &good[..good.len() - 2]).unwrap();
    assert!(matches!(checkpoint::load(&path),
                     Err(CheckpointError::Truncated { .. })));
    // ... and a closed front door at the backend builder
    assert!(matches!(ExecBackend::auto(&cfg_for(&path)),
                     Err(RuntimeError::Checkpoint(_))));

    let mut corrupt = good.clone();
    corrupt[3] ^= 0x40; // magic
    std::fs::write(&path, &corrupt).unwrap();
    assert!(matches!(checkpoint::load(&path), Err(CheckpointError::BadMagic)));
    assert!(ExecBackend::auto(&cfg_for(&path)).is_err());

    let mut corrupt = good.clone();
    corrupt[8..12].copy_from_slice(&7u32.to_le_bytes()); // version
    std::fs::write(&path, &corrupt).unwrap();
    assert!(matches!(checkpoint::load(&path),
                     Err(CheckpointError::UnsupportedVersion(7))));

    // wrong dims for the serving config (file itself is valid)
    std::fs::write(&path, &good).unwrap();
    let mut cfg = cfg_for(&path);
    cfg.layers = 5;
    assert!(matches!(ExecBackend::auto(&cfg),
                     Err(RuntimeError::Checkpoint(_))));

    std::fs::remove_file(&path).unwrap();
}
