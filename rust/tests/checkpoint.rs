//! Checkpoint acceptance tests (the loadable-weights contract):
//!
//! 1. `save → load` round-trips the encoder weights **bitwise**, and a
//!    coordinator serving the loaded checkpoint returns embeddings
//!    bitwise-equal to the stack that wrote it.
//! 2. Malformed files — truncated, corrupt header, wrong dims, trailing
//!    bytes — fail closed with typed [`CheckpointError`]s; serving with
//!    `init = load` on a bad file never starts.
//! 3. The `weights`/`init` knobs thread end to end through
//!    `ServingConfig` → `ExecBackend::auto` → `Coordinator`.

use ssaformer::config::{InitPolicy, ServingConfig, Variant};
use ssaformer::coordinator::{
    Coordinator, CpuModel, CpuModelConfig, ExecBackend,
};
use ssaformer::model::checkpoint::{self, CheckpointError};
use ssaformer::runtime::RuntimeError;
use std::path::PathBuf;
use std::sync::Arc;

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 23 + seed) % 2000)).collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "ssaformer-it-ckpt-{}-{name}.bin", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn saved_weights_serve_bitwise_through_the_coordinator() {
    // write a projected depth-3 model's weights ...
    let mcfg = CpuModelConfig {
        layers: 3, ffn_mult: 2, projections: true, ..Default::default()
    };
    let donor = CpuModel::new(mcfg, Variant::SpectralShift);
    let path = tmp("serve");
    checkpoint::save(donor.stack(), &path).unwrap();

    // ... then serve twice: seeded (the donor's config) vs loaded
    let serve = |weights: Option<String>| -> Vec<Vec<f32>> {
        let cfg = ServingConfig {
            artifacts_dir: "no/such/artifacts".into(),
            variant: Variant::SpectralShift,
            layers: 3,
            ffn_mult: 2,
            projections: true,
            init: if weights.is_some() { InitPolicy::Load }
                  else { InitPolicy::Seeded },
            weights,
            max_batch: 2,
            max_wait_ms: 2,
            queue_capacity: 32,
            workers: 1,
            cache_capacity: 0,
            ..Default::default()
        };
        cfg.validate().unwrap();
        let backend = ExecBackend::auto(&cfg).unwrap();
        let c = Arc::new(Coordinator::start(backend, &cfg).unwrap());
        (0..3)
            .map(|i| {
                c.submit_blocking(toks(60 + 30 * i, i as i32))
                    .unwrap().embedding.unwrap()
            })
            .collect()
    };
    let seeded = serve(None);
    let loaded = serve(Some(path.to_string_lossy().into_owned()));
    for (i, (a, b)) in seeded.iter().zip(&loaded).enumerate() {
        assert_eq!(bits(a), bits(b),
                   "req {i}: loaded checkpoint must serve the saved \
                    function bitwise");
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn roundtrip_is_bitwise_stable_across_a_second_save() {
    // save(load(save(m))) must produce byte-identical files — the
    // strongest cheap statement of lossless serialization
    let mcfg = CpuModelConfig {
        layers: 4, ffn_mult: 2, projections: true, ..Default::default()
    };
    let m = CpuModel::new(mcfg, Variant::Nystrom);
    let p1 = tmp("rt1");
    let p2 = tmp("rt2");
    checkpoint::save(m.stack(), &p1).unwrap();
    let ck = checkpoint::load(&p1).unwrap();
    let stack = ck.into_stack(m.stack().variants().to_vec()).unwrap();
    checkpoint::save(&stack, &p2).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap(),
               "re-serialization must be byte-identical");
    std::fs::remove_file(&p1).unwrap();
    std::fs::remove_file(&p2).unwrap();
}

#[test]
fn malformed_checkpoints_fail_closed_end_to_end() {
    let mcfg = CpuModelConfig { layers: 2, ..Default::default() };
    let donor = CpuModel::new(mcfg, Variant::SpectralShift);
    let path = tmp("mal");
    checkpoint::save(donor.stack(), &path).unwrap();
    let good = std::fs::read(&path).unwrap();

    let cfg_for = |p: &PathBuf| ServingConfig {
        artifacts_dir: "no/such/artifacts".into(),
        layers: 2,
        weights: Some(p.to_string_lossy().into_owned()),
        init: InitPolicy::Load,
        ..Default::default()
    };

    // typed errors at the parser ...
    std::fs::write(&path, &good[..good.len() - 2]).unwrap();
    assert!(matches!(checkpoint::load(&path),
                     Err(CheckpointError::Truncated { .. })));
    // ... and a closed front door at the backend builder
    assert!(matches!(ExecBackend::auto(&cfg_for(&path)),
                     Err(RuntimeError::Checkpoint(_))));

    let mut corrupt = good.clone();
    corrupt[3] ^= 0x40; // magic
    std::fs::write(&path, &corrupt).unwrap();
    assert!(matches!(checkpoint::load(&path), Err(CheckpointError::BadMagic)));
    assert!(ExecBackend::auto(&cfg_for(&path)).is_err());

    let mut corrupt = good.clone();
    corrupt[8..12].copy_from_slice(&7u32.to_le_bytes()); // version
    std::fs::write(&path, &corrupt).unwrap();
    assert!(matches!(checkpoint::load(&path),
                     Err(CheckpointError::UnsupportedVersion(7))));

    // wrong dims for the serving config (file itself is valid)
    std::fs::write(&path, &good).unwrap();
    let mut cfg = cfg_for(&path);
    cfg.layers = 5;
    assert!(matches!(ExecBackend::auto(&cfg),
                     Err(RuntimeError::Checkpoint(_))));

    std::fs::remove_file(&path).unwrap();
}

#[test]
fn trained_checkpoints_round_trip_and_fail_closed() {
    // same contract as the seeded donors above, but on weights the
    // in-repo trainer actually moved: projections on, multi-layer,
    // reloaded under a *mixed* per-layer variant assignment (the file
    // stores weights only — operators are a serving-time choice, so
    // the re-save must stay byte-identical even across variants)
    use ssaformer::attention::SpectralShiftConfig;
    use ssaformer::kernels::BatchedVariant;
    use ssaformer::train::{train_cpu, CpuTrainConfig};

    let tcfg = CpuTrainConfig {
        d_model: 16, n_heads: 2, ffn_mult: 2, layers: 3, vocab: 96,
        seq: 16, batch: 2, steps_per_epoch: 2, epochs: 1, seed: 23,
        corpus_lines: 60, workers: 1, ..Default::default()
    };
    let outcome = train_cpu(&tcfg);
    let p1 = tmp("trained1");
    let p2 = tmp("trained2");
    checkpoint::save(&outcome.stack, &p1).unwrap();

    let ck = checkpoint::load(&p1).unwrap();
    ck.check_shape(16, 2, 2, 3, true).unwrap();
    assert!(matches!(ck.check_shape(16, 2, 2, 4, true),
                     Err(CheckpointError::Mismatch { field: "layers", .. })));

    let mixed = vec![
        BatchedVariant::Full,
        BatchedVariant::SpectralShift(SpectralShiftConfig::new(8)),
        BatchedVariant::Nystrom { landmarks: 8, pinv_iters: 8 },
    ];
    let stack = ck.into_stack(mixed).unwrap();
    checkpoint::save(&stack, &p2).unwrap();
    let good = std::fs::read(&p1).unwrap();
    assert_eq!(good, std::fs::read(&p2).unwrap(),
               "trained save → load → mixed-variant stack → save must be \
                byte-identical");

    // the trained model also loads whole through the model constructor
    // under a mixed serving assignment ...
    let loaded = CpuModel::with_checkpoint(
        outcome.model_config,
        &[Variant::Full, Variant::SpectralShift, Variant::Nystrom],
        checkpoint::load(&p1).unwrap());
    assert!(loaded.is_ok(), "mixed-variant load of a trained checkpoint");

    // ... and the trained file fails closed exactly like a seeded one
    std::fs::write(&p1, &good[..good.len() - 3]).unwrap();
    assert!(matches!(checkpoint::load(&p1),
                     Err(CheckpointError::Truncated { .. })));
    let mut corrupt = good.clone();
    corrupt[2] ^= 0x08; // magic
    std::fs::write(&p1, &corrupt).unwrap();
    assert!(matches!(checkpoint::load(&p1), Err(CheckpointError::BadMagic)));

    std::fs::remove_file(&p1).unwrap();
    std::fs::remove_file(&p2).unwrap();
}
