//! `SSAF_KERNEL` override behavior, asserted through the
//! `kernels::active_isa()` probe.
//!
//! This lives in its own integration-test binary (= its own process) on
//! purpose: the override is read from the process environment, and
//! `active_isa()` deliberately does not cache it, so mutating the env
//! here cannot race the per-context arm pinning the in-process test
//! suites use (`KernelCtx::with_isa`). Everything runs in ONE `#[test]`
//! so the set/unset sequence is serial even if the harness adds threads.

use ssaformer::kernels::{active_isa, Isa};

#[test]
fn ssaf_kernel_env_selects_the_arm() {
    const KEY: &str = "SSAF_KERNEL";
    // the CI scalar lane runs the whole suite under SSAF_KERNEL=scalar —
    // stash whatever the harness was launched with and restore on exit
    let orig = std::env::var_os(KEY);
    std::env::remove_var(KEY);

    // no override: detection wins
    let detected = Isa::detect();
    assert_eq!(active_isa(), detected);

    // scalar is supported everywhere, so the override must always take
    std::env::set_var(KEY, "scalar");
    assert_eq!(active_isa(), Isa::Scalar);
    // a context constructed under the override carries the forced arm
    assert_eq!(ssaformer::kernels::KernelCtx::sequential().isa(),
               Isa::Scalar);

    // "auto" and empty both mean "no override" (back to detection)
    std::env::set_var(KEY, "auto");
    assert_eq!(active_isa(), detected);
    std::env::set_var(KEY, "");
    assert_eq!(active_isa(), detected);

    // every supported arm is selectable by token (spelled any case)
    for isa in Isa::available() {
        std::env::set_var(KEY, isa.token().to_ascii_uppercase());
        assert_eq!(active_isa(), isa);
    }

    // an unknown token is a hard panic, not a silent fallback — the CI
    // scalar lane depends on the override failing closed
    std::env::set_var(KEY, "sse9");
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {})); // keep the expected panic quiet
    let r = std::panic::catch_unwind(active_isa);
    std::panic::set_hook(hook);
    assert!(r.is_err(), "unknown SSAF_KERNEL token must panic");

    std::env::remove_var(KEY);
    assert_eq!(active_isa(), detected);

    if let Some(v) = orig {
        std::env::set_var(KEY, v);
    }
}
