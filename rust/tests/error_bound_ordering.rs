//! The paper's headline claim as a regression test: on **trained**
//! weights (not the seeded init), spectral shifting approximates exact
//! softmax attention at least as well as plain Nyström at every swept
//! landmark count.
//!
//! The assertion allows a small tie tolerance (`TIE_TOL`): the bound in
//! the paper is an inequality in expectation, and at very small landmark
//! counts the two estimators can land within noise of each other. A
//! genuine regression (ss clearly worse than nystrom) still fails; a
//! statistical tie does not. Raise `TIE_TOL` only with a comment citing
//! the observed gap.

use ssaformer::config::Variant;
use ssaformer::coordinator::CpuModel;
use ssaformer::eval::{error_bound_sweep, ErrorBoundConfig, EVAL_VARIANTS};
use ssaformer::kernels::Precision;
use ssaformer::train::{train_cpu, CpuTrainConfig};

/// Relative slack on `ss ≤ nystrom`: ss may exceed nystrom by at most 5%.
const TIE_TOL: f64 = 0.05;

#[test]
fn spectral_shift_beats_nystrom_on_trained_weights() {
    // seq 48 is divisible by every swept landmark count {4, 8, 16}
    let cfg = CpuTrainConfig {
        d_model: 16,
        n_heads: 2,
        ffn_mult: 2,
        layers: 3,
        vocab: 96,
        seq: 48,
        batch: 2,
        steps_per_epoch: 5,
        epochs: 2,
        seed: 19,
        corpus_lines: 80,
        workers: 1,
        ..Default::default()
    };
    let outcome = train_cpu(&cfg);
    assert!(outcome.report.epoch_loss_strictly_decreasing(),
            "precondition: the eval must run on weights that trained \
             (epoch losses {:?})", outcome.report.epoch_losses);

    let eval_cfg = ErrorBoundConfig {
        landmarks: vec![4, 8, 16],
        seq: cfg.seq,
        samples: 3,
        ..Default::default()
    };
    let model = CpuModel::new(outcome.model_config, Variant::Full);
    let report = error_bound_sweep(&model, &outcome.stack, &eval_cfg);

    // every cell of the sweep must be present and finite — including
    // the serving precision tiers (f32, bf16, int8)
    assert_eq!(report.rows.len(),
               EVAL_VARIANTS.len() * 3 * Precision::ALL.len(),
               "one row per variant per landmark count per precision");
    for row in &report.rows {
        assert!(row.mean_rel_err.is_finite() && row.max_rel_err.is_finite()
                && row.fro_ratio.is_finite(),
                "non-finite error for {} at c={} {}",
                row.variant, row.landmarks, row.precision);
    }

    // the quantized ss tiers are real measurements on trained weights:
    // present, nonzero, and distinct from the f32 row — the numbers the
    // admission tier table is calibrated against
    for p in [Precision::Bf16, Precision::Int8] {
        let q = report.mean_rel_err_at("ss", 16, p)
            .expect("quantized ss tier row present");
        let f = report.mean_rel_err_at("ss", 16, Precision::F32).unwrap();
        assert!(q.is_finite() && q > 0.0, "{}: {q}", p.token());
        assert_ne!(q, f, "{} row must be a measurement, not the f32 copy",
                   p.token());
    }

    for &c in &eval_cfg.landmarks {
        let ss = report.mean_rel_err("ss", c)
            .expect("ss row present at every landmark count");
        let ny = report.mean_rel_err("nystrom", c)
            .expect("nystrom row present at every landmark count");
        assert!(ss <= ny * (1.0 + TIE_TOL),
                "spectral shifting must not lose to nystrom at c={c}: \
                 ss mean rel err {ss} vs nystrom {ny} (tie tol {TIE_TOL})");
    }
}
