//! Integration: PJRT runtime over real AOT artifacts.
//!
//! Requires `make artifacts` to have run; every test skips gracefully
//! (with a loud message) when artifacts/ is missing so `cargo test`
//! stays usable on a fresh checkout.

use ssaformer::config::Variant;
use ssaformer::runtime::{ArtifactKind, Engine};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new("artifacts").expect("engine"))
}

#[test]
fn manifest_layout_is_consistent() {
    let Some(e) = engine() else { return };
    let m = e.manifest();
    assert!(m.param_count > 1_000_000);
    m.validate_layout().unwrap();
    assert!(m.find(ArtifactKind::Encode, Variant::SpectralShift, 128).is_some());
    // init params exist and match the count
    let p = e.init_params().unwrap();
    assert_eq!(p.len(), m.param_count);
    assert!(p.iter().all(|x| x.is_finite()));
}

#[test]
fn encode_artifact_runs_and_is_deterministic() {
    let Some(e) = engine() else { return };
    let model = e
        .load(ArtifactKind::Encode, Variant::SpectralShift, 128)
        .expect("load encode_ss");
    let params_host = e.init_params().unwrap();
    let params = e.buffer_f32(&params_host, &[params_host.len()]).unwrap();
    let b = model.entry.batch;
    let tokens: Vec<i32> = (0..b * 128).map(|i| 3 + (i as i32 % 1000)).collect();
    let emb1 = model.encode(&e, &params, &tokens).unwrap();
    let emb2 = model.encode(&e, &params, &tokens).unwrap();
    let d_model = e.manifest().hyper["d_model"] as usize;
    assert_eq!(emb1.len(), b * d_model);
    assert_eq!(emb1, emb2, "encode must be deterministic");
    assert!(emb1.iter().all(|x| x.is_finite()));
    // embeddings of different rows differ (model is not collapsing)
    assert!(emb1[..d_model] != emb1[d_model..2 * d_model]);
}

#[test]
fn encode_variants_agree_roughly_at_init() {
    // At random init all variants encode the same tokens through the
    // same weights; the approximations should be correlated with the
    // exact encoder but not identical.
    let Some(e) = engine() else { return };
    let params_host = e.init_params().unwrap();
    let params = e.buffer_f32(&params_host, &[params_host.len()]).unwrap();
    let tokens: Vec<i32> = (0..4 * 128).map(|i| 3 + (i as i32 * 7 % 2000)).collect();
    let mut outs = Vec::new();
    for v in [Variant::Full, Variant::Nystrom, Variant::SpectralShift] {
        let m = e.load(ArtifactKind::Encode, v, 128).expect("load");
        outs.push(m.encode(&e, &params, &tokens).unwrap());
    }
    let rel = |a: &[f32], b: &[f32]| -> f32 {
        let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum();
        let den: f32 = b.iter().map(|y| y.abs()).sum();
        num / den
    };
    let full = &outs[0];
    assert!(rel(&outs[1], full) < 1.0, "nystrom too far from full");
    assert!(rel(&outs[2], full) < 1.0, "ss too far from full");
    assert_ne!(outs[1], *full);
    // ss and nystrom nearly coincide at δ≈0 (full-rank landmark block)
    assert!(rel(&outs[2], &outs[1]) < 0.5);
}

#[test]
fn executable_cache_hits() {
    let Some(e) = engine() else { return };
    let t0 = std::time::Instant::now();
    let _m1 = e.load(ArtifactKind::Encode, Variant::Full, 128).unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _m2 = e.load(ArtifactKind::Encode, Variant::Full, 128).unwrap();
    let warm = t1.elapsed();
    assert!(warm < cold / 10, "cache miss on second load: {warm:?} vs {cold:?}");
}

#[test]
fn missing_artifact_is_not_found() {
    let Some(e) = engine() else { return };
    match e.load(ArtifactKind::Encode, Variant::Full, 9999) {
        Err(err) => assert!(err.to_string().contains("not found")),
        Ok(_) => panic!("expected NotFound"),
    }
}

#[test]
fn encode_rejects_wrong_token_count() {
    let Some(e) = engine() else { return };
    let model = e.load(ArtifactKind::Encode, Variant::Full, 128).unwrap();
    let params_host = e.init_params().unwrap();
    let params = e.buffer_f32(&params_host, &[params_host.len()]).unwrap();
    let bad = vec![0i32; 17];
    assert!(model.encode(&e, &params, &bad).is_err());
}
