//! End-to-end CPU-backend serving: full stack (TCP server → coordinator
//! → kernels::batched) with **no artifacts**, checked against the seed
//! scalar `attention::spectral_shift::reference` pipeline.
//!
//! Runs unconditionally — this is the path the offline build serves on.

use ssaformer::attention::spectral_shift::{reference, SpectralShiftConfig};
use ssaformer::attention::{softmax_attention, Tensor2};
use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{
    Coordinator, CpuEngine, CpuModel, CpuModelConfig, ExecBackend,
};
use ssaformer::runtime::BackendKind;
use ssaformer::server::{serve, Client};
use std::sync::Arc;

fn cpu_coordinator(variant: Variant) -> Arc<Coordinator> {
    let cfg = ServingConfig {
        variant,
        max_batch: 4,
        max_wait_ms: 5,
        queue_capacity: 64,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), variant)));
    Arc::new(Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap())
}

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 31 + seed) % 2000)).collect()
}

/// Reference pipeline, scalar path: embed exactly as the serving model
/// does, run the seed per-head attention, mean-pool the real rows.
fn expected_embedding(variant: Variant, tokens: &[i32]) -> Vec<f32> {
    let m = CpuModel::new(CpuModelConfig::default(), variant);
    let len = tokens.len();
    let plen = m.padded_len(len);
    let x = m.embed_sequence(tokens, plen);
    let (d, h) = (m.d_model(), m.n_heads());
    let dh = d / h;
    let mut full = Tensor2::zeros(plen, d);
    for head in 0..h {
        let mut xs = Tensor2::zeros(plen, dh);
        for i in 0..plen {
            for j in 0..dh {
                xs.data[i * dh + j] = x.data[i * d + head * dh + j];
            }
        }
        let oh = match variant {
            Variant::SpectralShift => {
                let mut cfg = SpectralShiftConfig::new(m.landmarks());
                cfg.pinv_iters = m.pinv_iters();
                reference::spectral_shift_attention_ref(&xs, &xs, &xs, &cfg)
            }
            Variant::Nystrom => reference::nystrom_attention_ref(
                &xs, &xs, &xs, m.landmarks(), m.pinv_iters(), None),
            Variant::Full => softmax_attention(&xs, &xs, &xs, None),
        };
        for i in 0..plen {
            for j in 0..dh {
                full.data[i * d + head * dh + j] = oh.data[i * dh + j];
            }
        }
    }
    let mut out = vec![0.0f32; d];
    for i in 0..len {
        for (o, v) in out.iter_mut()
            .zip(&full.data[i * d..(i + 1) * d]) {
            *o += *v;
        }
    }
    let inv = 1.0 / len as f32;
    out.iter_mut().for_each(|v| *v *= inv);
    out
}

/// 1e-4 kernel-parity budget plus half an ulp of the %.5f wire format.
fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-4 * b.abs().max(1.0) + 6e-6
}

#[test]
fn cpu_backend_serves_over_tcp_and_matches_reference() {
    let c = cpu_coordinator(Variant::SpectralShift);
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 4).unwrap();

    // concurrent clients, mixed lengths spanning several buckets
    let lengths = [40usize, 100, 128, 200, 300, 500];
    let mut joins = Vec::new();
    for (t, chunk) in lengths.chunks(2).enumerate() {
        let chunk: Vec<usize> = chunk.to_vec();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut got = Vec::new();
            for (i, &len) in chunk.iter().enumerate() {
                let id = (t * 10 + i) as u64;
                let tokens = toks(len, len as i32);
                let reply = client.encode(id, &tokens).unwrap();
                got.push((id, len, tokens, reply));
            }
            got
        }));
    }
    let mut total = 0;
    for j in joins {
        for (id, len, tokens, reply) in j.join().unwrap() {
            let parts: Vec<&str> = reply.split_whitespace().collect();
            assert_eq!(parts[0], "OK", "len {len}: {reply}");
            assert_eq!(parts[1], id.to_string());
            assert_eq!(parts.len(), 2 + 8, "{reply}");
            let want = expected_embedding(Variant::SpectralShift, &tokens);
            for (j, p) in parts[2..].iter().enumerate() {
                let a: f32 = p.parse().unwrap();
                assert!(close(a, want[j]),
                        "len {len} dim {j}: served {a} vs reference {}",
                        want[j]);
            }
            total += 1;
        }
    }
    assert_eq!(total, lengths.len());

    // STATS: backend identification + nonzero batched executions
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.contains("backend:  cpu-kernels"), "{stats}");
    let batches: u64 = stats
        .lines()
        .find(|l| l.starts_with("batches:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|n| n.parse().ok())
        .unwrap_or_else(|| panic!("no batches line in {stats}"));
    assert!(batches > 0, "{stats}");
    assert!(batches <= lengths.len() as u64, "{stats}");
    handle.stop();

    assert_eq!(c.metrics.requests_done.get(), lengths.len() as u64);
    assert!(c.metrics.batch_slots.get() >= c.metrics.batches_executed.get());
}

#[test]
fn full_precision_submit_matches_reference() {
    // submit_blocking bypasses the %.5f wire truncation: the whole
    // d_model embedding must sit inside the parity budget
    for variant in [Variant::SpectralShift, Variant::Full] {
        let c = cpu_coordinator(variant);
        let tokens = toks(100, 9);
        let emb = c.submit_blocking(tokens.clone()).unwrap().embedding.unwrap();
        let want = expected_embedding(variant, &tokens);
        assert_eq!(emb.len(), want.len());
        for (j, (a, b)) in emb.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "{variant:?} dim {j}: {a} vs {b}");
        }
    }
}

#[test]
fn auto_selects_cpu_without_artifacts_and_serves() {
    let cfg = ServingConfig {
        artifacts_dir: "no/such/artifacts".into(),
        max_batch: 2,
        max_wait_ms: 2,
        queue_capacity: 16,
        ..Default::default()
    };
    let backend = ExecBackend::auto(&cfg);
    assert_eq!(backend.kind(), BackendKind::Cpu);
    let c = Coordinator::start(backend, &cfg).unwrap();
    assert_eq!(c.backend(), BackendKind::Cpu);
    let emb = c.submit_blocking(toks(64, 1)).unwrap().embedding.unwrap();
    assert!(!emb.is_empty());
    assert!(emb.iter().all(|x| x.is_finite()));
}

#[test]
fn batching_fills_and_padding_is_metered() {
    // generous max_wait so a descheduled submitter on a loaded CI box
    // cannot age lanes out into 8 singleton batches
    let cfg = ServingConfig {
        variant: Variant::SpectralShift,
        max_batch: 4,
        max_wait_ms: 50,
        queue_capacity: 64,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), cfg.variant)));
    let c = Arc::new(Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
    // 8 same-bucket requests, batch capacity 4 → at least one multi-fill
    let mut rxs = Vec::new();
    for i in 0..8 {
        rxs.push(c.submit(toks(100 + i, i as i32)).unwrap());
    }
    for rx in rxs {
        assert!(rx.recv().unwrap().embedding.is_ok());
    }
    let m = &c.metrics;
    assert_eq!(m.requests_done.get(), 8);
    assert!(m.batches_executed.get() < 8, "no batching happened");
    // lengths 100..108 all pad up to 112 landmark-aligned positions
    assert!(m.padded_tokens.get() > 0);
    assert!(m.tokens_processed.get() >= 800);
}

#[test]
fn graceful_shutdown_drains_cpu_backend() {
    let c = cpu_coordinator(Variant::SpectralShift);
    let rx = c.submit(toks(80, 7)).unwrap();
    let c = Arc::try_unwrap(c).ok().expect("sole owner");
    c.shutdown();
    assert!(rx.recv().unwrap().embedding.is_ok());
}
