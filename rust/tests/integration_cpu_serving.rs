//! End-to-end CPU-backend serving: full stack (TCP server → coordinator
//! → sharded queue → worker pool → kernels::batched) with **no
//! artifacts**, checked against the seed scalar
//! `attention::spectral_shift::reference` pipeline. Also covers the
//! embedding cache (hit ≡ recompute, bounded eviction) and the deadline
//! path (`DEADLINE_MS` wire field, `ERR deadline`, early batch close).
//!
//! Runs unconditionally — this is the path the offline build serves on.

use ssaformer::attention::spectral_shift::{reference, SpectralShiftConfig};
use ssaformer::attention::{softmax_attention, Tensor2};
use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{
    Coordinator, CpuEngine, CpuModel, CpuModelConfig, EncodeRequest,
    ExecBackend,
};
use ssaformer::runtime::BackendKind;
use ssaformer::server::{serve, Client};
use std::sync::Arc;

fn cpu_coordinator(variant: Variant) -> Arc<Coordinator> {
    let cfg = ServingConfig {
        variant,
        max_batch: 4,
        max_wait_ms: 5,
        queue_capacity: 64,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), variant)));
    Arc::new(Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap())
}

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 31 + seed) % 2000)).collect()
}

/// Reference pipeline, scalar path: embed exactly as the serving model
/// does, run the seed per-head attention, mean-pool the real rows.
fn expected_embedding(variant: Variant, tokens: &[i32]) -> Vec<f32> {
    let m = CpuModel::new(CpuModelConfig::default(), variant);
    let len = tokens.len();
    let plen = m.padded_len(len);
    let x = m.embed_sequence(tokens, plen);
    let (d, h) = (m.d_model(), m.n_heads());
    let dh = d / h;
    let mut full = Tensor2::zeros(plen, d);
    for head in 0..h {
        let mut xs = Tensor2::zeros(plen, dh);
        for i in 0..plen {
            for j in 0..dh {
                xs.data[i * dh + j] = x.data[i * d + head * dh + j];
            }
        }
        let oh = match variant {
            Variant::SpectralShift => {
                let mut cfg = SpectralShiftConfig::new(m.landmarks());
                cfg.pinv_iters = m.pinv_iters();
                reference::spectral_shift_attention_ref(&xs, &xs, &xs, &cfg)
            }
            Variant::Nystrom => reference::nystrom_attention_ref(
                &xs, &xs, &xs, m.landmarks(), m.pinv_iters(), None),
            Variant::Full => softmax_attention(&xs, &xs, &xs, None),
            other => panic!("no scalar reference wired here for {other:?}"),
        };
        for i in 0..plen {
            for j in 0..dh {
                full.data[i * d + head * dh + j] = oh.data[i * dh + j];
            }
        }
    }
    let mut out = vec![0.0f32; d];
    for i in 0..len {
        for (o, v) in out.iter_mut()
            .zip(&full.data[i * d..(i + 1) * d]) {
            *o += *v;
        }
    }
    let inv = 1.0 / len as f32;
    out.iter_mut().for_each(|v| *v *= inv);
    out
}

/// 1e-4 kernel-parity budget plus half an ulp of the %.5f wire format.
fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-4 * b.abs().max(1.0) + 6e-6
}

#[test]
fn cpu_backend_serves_over_tcp_and_matches_reference() {
    let c = cpu_coordinator(Variant::SpectralShift);
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 4).unwrap();

    // concurrent clients, mixed lengths spanning several buckets
    let lengths = [40usize, 100, 128, 200, 300, 500];
    let mut joins = Vec::new();
    for (t, chunk) in lengths.chunks(2).enumerate() {
        let chunk: Vec<usize> = chunk.to_vec();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut got = Vec::new();
            for (i, &len) in chunk.iter().enumerate() {
                let id = (t * 10 + i) as u64;
                let tokens = toks(len, len as i32);
                let reply = client.encode(id, &tokens).unwrap();
                got.push((id, len, tokens, reply));
            }
            got
        }));
    }
    let mut total = 0;
    for j in joins {
        for (id, len, tokens, reply) in j.join().unwrap() {
            let parts: Vec<&str> = reply.split_whitespace().collect();
            assert_eq!(parts[0], "OK", "len {len}: {reply}");
            assert_eq!(parts[1], id.to_string());
            assert_eq!(parts.len(), 2 + 8, "{reply}");
            let want = expected_embedding(Variant::SpectralShift, &tokens);
            for (j, p) in parts[2..].iter().enumerate() {
                let a: f32 = p.parse().unwrap();
                assert!(close(a, want[j]),
                        "len {len} dim {j}: served {a} vs reference {}",
                        want[j]);
            }
            total += 1;
        }
    }
    assert_eq!(total, lengths.len());

    // STATS: backend identification + nonzero batched executions
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.contains("backend:  cpu-kernels"), "{stats}");
    // the kernel line names the active micro-kernel arm and the GEMM
    // blocking parameters (KC/NC) the NS chain depends on
    let kernel_line = stats.lines().find(|l| l.starts_with("kernel:"))
        .unwrap_or_else(|| panic!("no kernel line in {stats}"));
    assert!(kernel_line.contains(
                ssaformer::kernels::active_isa().token()),
            "{kernel_line}");
    assert!(kernel_line.contains("KC=") && kernel_line.contains("NC="),
            "{kernel_line}");
    let batches: u64 = stats
        .lines()
        .find(|l| l.starts_with("batches:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|n| n.parse().ok())
        .unwrap_or_else(|| panic!("no batches line in {stats}"));
    assert!(batches > 0, "{stats}");
    assert!(batches <= lengths.len() as u64, "{stats}");
    handle.stop();

    assert_eq!(c.metrics.requests_done.get(), lengths.len() as u64);
    assert!(c.metrics.batch_slots.get() >= c.metrics.batches_executed.get());
}

#[test]
fn full_precision_submit_matches_reference() {
    // submit_blocking bypasses the %.5f wire truncation: the whole
    // d_model embedding must sit inside the parity budget
    for variant in [Variant::SpectralShift, Variant::Full] {
        let c = cpu_coordinator(variant);
        let tokens = toks(100, 9);
        let emb = c.submit_blocking(tokens.clone()).unwrap().embedding.unwrap();
        let want = expected_embedding(variant, &tokens);
        assert_eq!(emb.len(), want.len());
        for (j, (a, b)) in emb.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "{variant:?} dim {j}: {a} vs {b}");
        }
    }
}

#[test]
fn auto_selects_cpu_without_artifacts_and_serves() {
    let cfg = ServingConfig {
        artifacts_dir: "no/such/artifacts".into(),
        max_batch: 2,
        max_wait_ms: 2,
        queue_capacity: 16,
        ..Default::default()
    };
    let backend = ExecBackend::auto(&cfg).unwrap();
    assert_eq!(backend.kind(), BackendKind::Cpu);
    let c = Coordinator::start(backend, &cfg).unwrap();
    assert_eq!(c.backend(), BackendKind::Cpu);
    let emb = c.submit_blocking(toks(64, 1)).unwrap().embedding.unwrap();
    assert!(!emb.is_empty());
    assert!(emb.iter().all(|x| x.is_finite()));
}

#[test]
fn batching_fills_and_padding_is_metered() {
    // generous max_wait so a descheduled submitter on a loaded CI box
    // cannot age lanes out into 8 singleton batches
    let cfg = ServingConfig {
        variant: Variant::SpectralShift,
        max_batch: 4,
        max_wait_ms: 50,
        queue_capacity: 64,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), cfg.variant)));
    let c = Arc::new(Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
    // 8 same-bucket requests, batch capacity 4 → at least one multi-fill
    let mut rxs = Vec::new();
    for i in 0..8 {
        rxs.push(c.submit(toks(100 + i, i as i32)).unwrap());
    }
    for rx in rxs {
        assert!(rx.recv().unwrap().embedding.is_ok());
    }
    let m = &c.metrics;
    assert_eq!(m.requests_done.get(), 8);
    assert!(m.batches_executed.get() < 8, "no batching happened");
    // lengths 100..108 all pad up to 112 landmark-aligned positions
    assert!(m.padded_tokens.get() > 0);
    assert!(m.tokens_processed.get() >= 800);
}

#[test]
fn graceful_shutdown_drains_cpu_backend() {
    let c = cpu_coordinator(Variant::SpectralShift);
    let rx = c.submit(toks(80, 7)).unwrap();
    let c = Arc::try_unwrap(c).ok().expect("sole owner");
    c.shutdown();
    assert!(rx.recv().unwrap().embedding.is_ok());
}

#[test]
fn four_workers_with_cache_serve_parity_and_register_hits() {
    // the acceptance scenario: N=4 workers over 2 shards, cache on
    let cfg = ServingConfig {
        variant: Variant::SpectralShift,
        max_batch: 4,
        max_wait_ms: 5,
        queue_capacity: 64,
        workers: 4,
        queue_shards: 2,
        cache_capacity: 64,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), cfg.variant)));
    let c = Arc::new(Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap());
    assert_eq!((c.workers(), c.queue_shards()), (4, 2));

    // phase 1: 8 distinct concurrent requests across buckets — every
    // embedding must match the scalar reference at full precision
    let lengths = [40usize, 100, 128, 200, 260, 300, 400, 500];
    let mut joins = Vec::new();
    for &len in &lengths {
        let c = c.clone();
        joins.push(std::thread::spawn(move || {
            let tokens = toks(len, len as i32);
            let emb = c.submit_blocking(tokens.clone()).unwrap()
                .embedding.unwrap();
            (tokens, emb)
        }));
    }
    let mut first: Vec<(Vec<i32>, Vec<f32>)> = Vec::new();
    for j in joins {
        first.push(j.join().unwrap());
    }
    for (tokens, emb) in &first {
        let want = expected_embedding(Variant::SpectralShift, tokens);
        for (j, (a, b)) in emb.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "len {} dim {j}: {a} vs {b}", tokens.len());
        }
    }
    assert_eq!(c.metrics.cache_hits.get(), 0, "phase 1 had no repeats");

    // phase 2: repeat every sequence — all hits, all bitwise-equal to
    // the computed originals (the cache-coherence invariant)
    for (tokens, emb) in &first {
        let again = c.submit_blocking(tokens.clone()).unwrap()
            .embedding.unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&again), bits(emb), "hit must equal recompute bitwise");
    }
    assert_eq!(c.metrics.cache_hits.get(), lengths.len() as u64);
    assert_eq!(c.metrics.requests_done.get(), 2 * lengths.len() as u64);
}

#[test]
fn cache_evicts_under_capacity_pressure() {
    let cfg = ServingConfig {
        variant: Variant::SpectralShift,
        max_batch: 4,
        max_wait_ms: 2,
        queue_capacity: 64,
        cache_capacity: 4,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), cfg.variant)));
    let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
    let t = toks(64, 1);
    let first = c.submit_blocking(t.clone()).unwrap().embedding.unwrap();
    assert_eq!(c.submit_blocking(t.clone()).unwrap().embedding.unwrap(), first);
    assert_eq!(c.metrics.cache_hits.get(), 1);
    // 6 distinct sequences push through a 4-entry cache → t evicted
    for s in 10..16 {
        c.submit_blocking(toks(64, s)).unwrap().embedding.unwrap();
    }
    assert!(c.cache_len() <= 4, "cache grew past capacity: {}", c.cache_len());
    let misses_before = c.metrics.cache_misses.get();
    let recomputed = c.submit_blocking(t).unwrap().embedding.unwrap();
    assert_eq!(c.metrics.cache_misses.get(), misses_before + 1,
               "evicted entry must miss");
    // determinism: the recompute still equals the original bitwise
    assert_eq!(recomputed, first);
}

#[test]
fn expired_deadline_gets_err_deadline_over_tcp_without_batch_slot() {
    let c = cpu_coordinator(Variant::SpectralShift);
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    // a zero budget has always expired by admission time
    let reply = client.encode_with_deadline(9, &toks(100, 3), 0).unwrap();
    assert_eq!(reply, "ERR 9 deadline");
    assert_eq!(c.metrics.requests_expired.get(), 1);
    // no batch slot was consumed anywhere
    assert_eq!(c.metrics.batch_slots.get(), 0);
    assert_eq!(c.metrics.batches_executed.get(), 0);
    assert_eq!(c.metrics.requests_done.get(), 0);
    // a generous deadline on the same connection still serves
    let reply = client.encode_with_deadline(10, &toks(100, 3), 60_000).unwrap();
    assert!(reply.starts_with("OK 10 "), "{reply}");
    // malformed deadline value is rejected, not silently dropped
    let stats = client.stats().unwrap();
    assert!(stats.contains("expired=1"), "{stats}");
    assert!(stats.contains("workers:  2"), "{stats}");
    handle.stop();
}

#[test]
fn deadline_pressure_closes_partial_batch_early() {
    // one lonely request, a 30s batching window, but a 2s deadline:
    // the batcher must close the bucket at deadline − margin instead of
    // holding the request for max_wait
    let cfg = ServingConfig {
        variant: Variant::SpectralShift,
        max_batch: 4,
        max_wait_ms: 30_000,
        queue_capacity: 64,
        deadline_margin_ms: 500,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), cfg.variant)));
    let c = Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap();
    let t0 = std::time::Instant::now();
    let rx = c.submit(EncodeRequest::new(toks(100, 5))
        .deadline(std::time::Duration::from_millis(2000))).unwrap();
    let resp = rx.recv().unwrap();
    let waited = t0.elapsed();
    assert!(resp.embedding.is_ok(), "{:?}", resp.embedding);
    assert!(waited < std::time::Duration::from_secs(20),
            "deadline did not close the batch early: {waited:?}");
    assert!(waited >= std::time::Duration::from_millis(1000),
            "batch closed before deadline pressure: {waited:?}");
    assert_eq!(c.metrics.requests_expired.get(), 0);
}
