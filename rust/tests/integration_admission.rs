//! End-to-end accuracy-aware admission over TCP: the `ACCURACY=` wire
//! option must demonstrably route a request onto a different
//! `(variant, precision)` tier than an untagged request — visible in
//! the `OK` reply's ` tier=` metadata and on the STATS `admission:`
//! line — while `ACCURACY=high` on a full-variant server stays
//! byte-identical to the no-option wire reply. Also pins the
//! `bad-option` error taxonomy of the shared option grammar
//! ([`ssaformer::server::options`]).

use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{
    Coordinator, CpuEngine, CpuModel, CpuModelConfig, ExecBackend, TierKind,
};
use ssaformer::server::{serve, Client};
use std::sync::Arc;

fn cpu_coordinator(variant: Variant,
                   admission: Option<TierKind>) -> Arc<Coordinator> {
    let cfg = ServingConfig {
        variant,
        max_batch: 4,
        max_wait_ms: 5,
        queue_capacity: 64,
        admission,
        ..Default::default()
    };
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), variant)));
    Arc::new(Coordinator::start(ExecBackend::Cpu(engine), &cfg).unwrap())
}

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 31 + seed) % 2000)).collect()
}

/// Split an `OK <id> <f1..f8>[ tier=<t>]` reply into the 8 float
/// fields and the optional tier token.
fn split_ok(reply: &str, id: u64) -> (Vec<String>, Option<String>) {
    let parts: Vec<&str> = reply.split_whitespace().collect();
    assert_eq!(parts[0], "OK", "{reply}");
    assert_eq!(parts[1], id.to_string(), "{reply}");
    let tier = parts.last().and_then(|p| p.strip_prefix("tier="));
    match tier {
        Some(t) => {
            assert_eq!(parts.len(), 2 + 8 + 1, "{reply}");
            (parts[2..10].iter().map(|s| s.to_string()).collect(),
             Some(t.to_string()))
        }
        None => {
            assert_eq!(parts.len(), 2 + 8, "{reply}");
            (parts[2..].iter().map(|s| s.to_string()).collect(), None)
        }
    }
}

#[test]
fn accuracy_tags_route_tiers_and_meter_the_stats_line() {
    let c = cpu_coordinator(Variant::SpectralShift, None);
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();

    // untagged: the configured path, no tier metadata on the wire
    let (_, tier) = split_ok(&client.encode(1, &toks(100, 1)).unwrap(), 1);
    assert_eq!(tier, None, "untagged requests must not grow a suffix");

    // a budget tag must land on the quantized tier and say so
    let reply = client.encode_with(2, "ACCURACY=budget", &toks(100, 1))
        .unwrap();
    let (_, tier) = split_ok(&reply, 2);
    assert_eq!(tier.as_deref(), Some("ss-int8"), "{reply}");

    // a high tag forces exact attention at f32 even on an ss server
    let reply = client.encode_with(3, "ACCURACY=high", &toks(100, 1))
        .unwrap();
    let (_, tier) = split_ok(&reply, 3);
    assert_eq!(tier.as_deref(), Some("full-f32"), "{reply}");

    // options compose: a deadline rides along with the accuracy tag
    let reply = client
        .encode_with(4, "DEADLINE_MS=60000 ACCURACY=budget", &toks(100, 1))
        .unwrap();
    let (_, tier) = split_ok(&reply, 4);
    assert_eq!(tier.as_deref(), Some("ss-int8"), "{reply}");

    // STATS: the policy header names every available tier and the
    // admission line shows where the four requests actually landed
    let stats = client.stats().unwrap();
    let policy = stats.lines().find(|l| l.starts_with("policy:"))
        .unwrap_or_else(|| panic!("no policy line in {stats}"));
    assert!(policy.contains("policy=auto"), "{policy}");
    for t in TierKind::ALL {
        assert!(policy.contains(t.token()), "{policy} missing {}", t.token());
    }
    let admission = stats.lines().find(|l| l.starts_with("admission:"))
        .unwrap_or_else(|| panic!("no admission line in {stats}"));
    assert!(admission.contains("configured=1"), "{admission}");
    assert!(admission.contains("ss-int8=2"), "{admission}");
    assert!(admission.contains("full-f32=1"), "{admission}");
    assert!(admission.contains("ss-bf16=0"), "{admission}");
    handle.stop();
}

#[test]
fn accuracy_high_is_bitwise_the_untagged_reply_on_a_full_server() {
    // on a full-variant server the high tier IS the configured model
    // (a bitwise weight copy), so the 8 wire floats must match the
    // untagged reply byte for byte — only the tier suffix differs
    let c = cpu_coordinator(Variant::Full, None);
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();

    let t = toks(90, 7);
    let (plain, tier) = split_ok(&client.encode(1, &t).unwrap(), 1);
    assert_eq!(tier, None);
    let (tagged, tier) =
        split_ok(&client.encode_with(2, "ACCURACY=high", &t).unwrap(), 2);
    assert_eq!(tier.as_deref(), Some("full-f32"));
    assert_eq!(plain, tagged,
               "the full-f32 tier must be byte-identical to the \
                configured full path");
    handle.stop();
}

#[test]
fn forced_admission_knob_routes_untagged_requests() {
    // [serving] admission = "ss-bf16": every request lands on the
    // forced tier without any wire tag, and the policy line says so
    let c = cpu_coordinator(Variant::SpectralShift, Some(TierKind::SsBf16));
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();

    let (_, tier) = split_ok(&client.encode(1, &toks(64, 2)).unwrap(), 1);
    assert_eq!(tier.as_deref(), Some("ss-bf16"));
    let stats = client.stats().unwrap();
    let policy = stats.lines().find(|l| l.starts_with("policy:"))
        .unwrap_or_else(|| panic!("no policy line in {stats}"));
    assert!(policy.contains("policy=forced-ss-bf16"), "{policy}");
    let admission = stats.lines().find(|l| l.starts_with("admission:"))
        .unwrap_or_else(|| panic!("no admission line in {stats}"));
    assert!(admission.contains("ss-bf16=1"), "{admission}");
    assert!(admission.contains("configured=0"), "{admission}");
    handle.stop();
}

#[test]
fn env_override_forces_every_untagged_request() {
    // meaningful only under the CI admission lane, which runs this test
    // once per tier with SSAF_ADMISSION set; a plain `cargo test` run
    // (env unset, or explicitly `auto`) exits without asserting
    let Ok(raw) = std::env::var("SSAF_ADMISSION") else { return };
    if raw.trim().eq_ignore_ascii_case("auto") {
        return;
    }
    let want = TierKind::parse(&raw).expect("lane sets a valid tier");
    let c = cpu_coordinator(Variant::SpectralShift, None);
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let (_, tier) = split_ok(&client.encode(1, &toks(64, 3)).unwrap(), 1);
    assert_eq!(tier.as_deref(), Some(want.token()),
               "SSAF_ADMISSION={raw} must route untagged traffic");
    let stats = client.stats().unwrap();
    assert!(stats.contains(&format!("policy=forced-{}", want.token())),
            "{stats}");
    handle.stop();
}

#[test]
fn bad_options_fail_closed_over_the_wire() {
    let c = cpu_coordinator(Variant::SpectralShift, None);
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let t = toks(16, 1);

    // unknown key: a typo must not silently become a skipped token
    assert_eq!(client.encode_with(7, "PRIORITY=3", &t).unwrap(),
               "ERR 7 bad-option");
    // duplicate keys have no right answer
    assert_eq!(client.encode_with(8, "ACCURACY=high ACCURACY=budget", &t)
                   .unwrap(),
               "ERR 8 bad-option");
    // unparsable accuracy value
    assert_eq!(client.encode_with(9, "ACCURACY=speedy", &t).unwrap(),
               "ERR 9 bad-option");
    // empty value
    assert_eq!(client.encode_with(10, "ACCURACY=", &t).unwrap(),
               "ERR 10 bad-option");
    // the deadline keeps its historical error token
    assert_eq!(client.encode_with(11, "DEADLINE_MS=abc", &t).unwrap(),
               "ERR 11 bad-deadline");
    // a rejected option never consumed a queue slot or a counter
    assert_eq!(c.metrics.requests_in.get(), 0, "rejected lines must not \
                count as admitted requests");
    // and a good line still works on the same connection
    let (_, tier) =
        split_ok(&client.encode_with(12, "ACCURACY=0.05", &t).unwrap(), 12);
    assert!(tier.is_some(), "numeric bound routes to a tier");
    handle.stop();
}
