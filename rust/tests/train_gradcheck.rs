//! Finite-difference gradient checks for every trainer backward kernel
//! (the `train::backward` VJPs): GEMM, layernorm, bias+GELU, softmax
//! attention, and the full projection seam.
//!
//! Method: the forward is re-implemented here as an **f64 twin** of the
//! f32 production math (same formulas, same literal constants widened
//! to f64), a scalar loss `L = Σ W ⊙ f(θ)` is differentiated by f64
//! central differences, and the f32 analytic gradient from
//! `train::backward` is compared at tolerance ≤ 1e-3. Doing the
//! differences in f64 is what makes the tolerance reachable: f32
//! central differences at useful step sizes drown in rounding noise.

use ssaformer::attention::{default_scale, Tensor2};
use ssaformer::kernels::{softmax_scores, KernelCtx, Workspace};
use ssaformer::rngx::Rng;
use ssaformer::train::backward::{
    bias_gelu_backward, gemm_backward_acc, layernorm_backward, mha_backward,
    mha_forward, softmax_attention_backward, MhaGrads,
};

const TOL: f64 = 1e-3;
const H: f64 = 1e-4;

fn check(name: &str, analytic: f32, fd: f64) {
    let a = analytic as f64;
    let denom = fd.abs().max(1.0);
    assert!(
        (a - fd).abs() <= TOL * denom,
        "{name}: analytic {a} vs central-difference {fd} (tol {TOL})"
    );
}

fn randn(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Tensor2 {
    Tensor2::randn(rng, rows, cols, std)
}

fn to64(t: &Tensor2) -> Vec<f64> {
    t.data.iter().map(|&x| x as f64).collect()
}

// ---- f64 twin forwards (same formulas/constants as kernels::) -------

fn gemm64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
    c
}

fn layernorm64(x: &[f64], gain: &[f64], bias: &[f64], n: usize, d: usize,
               eps: f64) -> Vec<f64> {
    let mut y = vec![0.0; n * d];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let mean = row.iter().sum::<f64>() / d as f64;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f64>()
            / d as f64;
        let inv = 1.0 / (var + eps).sqrt();
        for j in 0..d {
            y[i * d + j] = (row[j] - mean) * inv * gain[j] + bias[j];
        }
    }
    y
}

fn gelu64(z: f64) -> f64 {
    // literal f32 constants of kernels::gelu, widened
    let c = 0.797_884_56f32 as f64;
    let k = 0.044_715f32 as f64;
    0.5 * z * (1.0 + (c * (z + k * z * z * z)).tanh())
}

fn softmax_attn64(q: &[f64], k: &[f64], v: &[f64], n: usize, dh: usize,
                  scale: f64) -> Vec<f64> {
    let mut s = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut dot = 0.0;
            for p in 0..dh {
                dot += q[i * dh + p] * k[j * dh + p];
            }
            s[i * n + j] = scale * dot;
        }
        let row = &mut s[i * n..(i + 1) * n];
        let max = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    gemm64(&s, v, n, n, dh)
}

fn mha64(x: &[f64], wq: &[f64], wk: &[f64], wv: &[f64], wo: &[f64],
         n: usize, d: usize, heads: usize) -> Vec<f64> {
    let dh = d / heads;
    let scale = default_scale(dh) as f64;
    let mut merged = vec![0.0; n * d];
    for h in 0..heads {
        let ws = h * d * dh..(h + 1) * d * dh;
        let q = gemm64(x, &wq[ws.clone()], n, d, dh);
        let k = gemm64(x, &wk[ws.clone()], n, d, dh);
        let v = gemm64(x, &wv[ws], n, d, dh);
        let o = softmax_attn64(&q, &k, &v, n, dh, scale);
        for i in 0..n {
            merged[i * d + h * dh..i * d + (h + 1) * dh]
                .copy_from_slice(&o[i * dh..(i + 1) * dh]);
        }
    }
    gemm64(&merged, wo, n, d, d)
}

fn dot64(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Central difference of `loss(θ)` w.r.t. `theta[idx]`.
fn central<F: Fn(&[f64]) -> f64>(theta: &[f64], idx: usize, loss: F) -> f64 {
    let mut plus = theta.to_vec();
    plus[idx] += H;
    let mut minus = theta.to_vec();
    minus[idx] -= H;
    (loss(&plus) - loss(&minus)) / (2.0 * H)
}

// ---- the checks -----------------------------------------------------

#[test]
fn gemm_backward_matches_central_differences() {
    let (m, k, n) = (3, 4, 2);
    let mut rng = Rng::new(100);
    let a = randn(&mut rng, m, k, 1.0);
    let b = randn(&mut rng, k, n, 1.0);
    let w = randn(&mut rng, m, n, 1.0); // loss weights: L = Σ W⊙(A·B)
    let mut d_a = vec![0.0f32; m * k];
    let mut d_b = vec![0.0f32; k * n];
    let ctx = KernelCtx::sequential();
    let mut ws = Workspace::new();
    gemm_backward_acc(&ctx, &a.data, &b.data, &w.data, m, k, n, &mut d_a,
                      &mut d_b, &mut ws);

    let (a64, b64, w64) = (to64(&a), to64(&b), to64(&w));
    for idx in 0..m * k {
        let fd = central(&a64, idx,
                         |t| dot64(&gemm64(t, &b64, m, k, n), &w64));
        check(&format!("gemm dA[{idx}]"), d_a[idx], fd);
    }
    for idx in 0..k * n {
        let fd = central(&b64, idx,
                         |t| dot64(&gemm64(&a64, t, m, k, n), &w64));
        check(&format!("gemm dB[{idx}]"), d_b[idx], fd);
    }
}

#[test]
fn layernorm_backward_matches_central_differences() {
    let (n, d) = (3, 8);
    let eps = 1e-5f64;
    let mut rng = Rng::new(101);
    let x = randn(&mut rng, n, d, 1.0);
    let gain = randn(&mut rng, 1, d, 0.5);
    let bias = randn(&mut rng, 1, d, 0.5);
    let w = randn(&mut rng, n, d, 1.0);
    let mut d_x = Tensor2::zeros(n, d);
    let mut d_gain = vec![0.0f32; d];
    let mut d_bias = vec![0.0f32; d];
    layernorm_backward(&x, &gain.data, eps as f32, &w, &mut d_x, &mut d_gain,
                       &mut d_bias);

    let (x64, g64, b64, w64) = (to64(&x), to64(&gain), to64(&bias), to64(&w));
    for idx in 0..n * d {
        let fd = central(&x64, idx,
                         |t| dot64(&layernorm64(t, &g64, &b64, n, d, eps),
                                   &w64));
        check(&format!("layernorm dx[{idx}]"), d_x.data[idx], fd);
    }
    for idx in 0..d {
        let fd = central(&g64, idx,
                         |t| dot64(&layernorm64(&x64, t, &b64, n, d, eps),
                                   &w64));
        check(&format!("layernorm dgain[{idx}]"), d_gain[idx], fd);
        let fd = central(&b64, idx,
                         |t| dot64(&layernorm64(&x64, &g64, t, n, d, eps),
                                   &w64));
        check(&format!("layernorm dbias[{idx}]"), d_bias[idx], fd);
    }
}

#[test]
fn bias_gelu_backward_matches_central_differences() {
    let (n, d) = (3, 6);
    let mut rng = Rng::new(102);
    let x = randn(&mut rng, n, d, 1.5);
    let bias = randn(&mut rng, 1, d, 0.5);
    let w = randn(&mut rng, n, d, 1.0);
    // recorded pre-activation z = x + bias (broadcast over rows)
    let mut z = Tensor2::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            z.data[i * d + j] = x.data[i * d + j] + bias.data[j];
        }
    }
    let mut d_pre = Tensor2::zeros(n, d);
    let mut d_bias = vec![0.0f32; d];
    bias_gelu_backward(&z, &w, &mut d_pre, &mut d_bias);

    let (x64, b64, w64) = (to64(&x), to64(&bias), to64(&w));
    let loss = |xv: &[f64], bv: &[f64]| -> f64 {
        let mut l = 0.0;
        for i in 0..n {
            for j in 0..d {
                l += w64[i * d + j] * gelu64(xv[i * d + j] + bv[j]);
            }
        }
        l
    };
    for idx in 0..n * d {
        let fd = central(&x64, idx, |t| loss(t, &b64));
        check(&format!("bias_gelu dx[{idx}]"), d_pre.data[idx], fd);
    }
    for idx in 0..d {
        let fd = central(&b64, idx, |t| loss(&x64, t));
        check(&format!("bias_gelu dbias[{idx}]"), d_bias[idx], fd);
    }
}

#[test]
fn softmax_attention_backward_matches_central_differences() {
    let (n, dh) = (6, 4);
    let scale = default_scale(dh);
    let mut rng = Rng::new(103);
    let q = randn(&mut rng, n, dh, 1.0);
    let k = randn(&mut rng, n, dh, 1.0);
    let v = randn(&mut rng, n, dh, 1.0);
    let w = randn(&mut rng, n, dh, 1.0);
    let ctx = KernelCtx::sequential();
    let mut ws = Workspace::new();
    let s = softmax_scores(&ctx, &q, &k, scale, &mut ws);
    let s = Tensor2 { rows: s.rows, cols: s.cols, data: s.data.clone() };
    let (dq, dk, dv) =
        softmax_attention_backward(&ctx, &q, &k, &v, &s, scale, &w, &mut ws);

    let (q64, k64, v64, w64) = (to64(&q), to64(&k), to64(&v), to64(&w));
    let s64 = scale as f64;
    for idx in 0..n * dh {
        let fd = central(&q64, idx,
                         |t| dot64(&softmax_attn64(t, &k64, &v64, n, dh, s64),
                                   &w64));
        check(&format!("attn dq[{idx}]"), dq.data[idx], fd);
        let fd = central(&k64, idx,
                         |t| dot64(&softmax_attn64(&q64, t, &v64, n, dh, s64),
                                   &w64));
        check(&format!("attn dk[{idx}]"), dk.data[idx], fd);
        let fd = central(&v64, idx,
                         |t| dot64(&softmax_attn64(&q64, &k64, t, n, dh, s64),
                                   &w64));
        check(&format!("attn dv[{idx}]"), dv.data[idx], fd);
    }
}

#[test]
fn projection_seam_backward_matches_central_differences() {
    let (n, d, heads) = (6, 8, 2);
    let dh = d / heads;
    let mut rng = Rng::new(104);
    let x = randn(&mut rng, n, d, 1.0);
    let wq = randn(&mut rng, heads * d, dh, 0.4).data;
    let wk = randn(&mut rng, heads * d, dh, 0.4).data;
    let wv = randn(&mut rng, heads * d, dh, 0.4).data;
    let wo = randn(&mut rng, d, d, 0.4).data;
    let w = randn(&mut rng, n, d, 1.0);
    let ctx = KernelCtx::sequential();
    let mut ws = Workspace::new();
    let (out, cache) = mha_forward(&ctx, &x, &wq, &wk, &wv, &wo, heads,
                                   &mut ws);
    let mut grads = MhaGrads::zeros(d, heads);
    let d_x = mha_backward(&ctx, &x, &wq, &wk, &wv, &wo, heads, &cache, &w,
                           &mut grads, &mut ws);

    // the recorded forward must itself agree with the f64 twin (sanity
    // that both checks below differentiate the same function)
    let x64 = to64(&x);
    let wq64: Vec<f64> = wq.iter().map(|&v| v as f64).collect();
    let wk64: Vec<f64> = wk.iter().map(|&v| v as f64).collect();
    let wv64: Vec<f64> = wv.iter().map(|&v| v as f64).collect();
    let wo64: Vec<f64> = wo.iter().map(|&v| v as f64).collect();
    let w64 = to64(&w);
    let twin = mha64(&x64, &wq64, &wk64, &wv64, &wo64, n, d, heads);
    for (i, (&a, &t)) in out.data.iter().zip(&twin).enumerate() {
        assert!(((a as f64) - t).abs() < 1e-4,
                "forward twin diverges at {i}: {a} vs {t}");
    }

    let loss = |xv: &[f64], q: &[f64], k: &[f64], v: &[f64], o: &[f64]| {
        dot64(&mha64(xv, q, k, v, o, n, d, heads), &w64)
    };
    // spot-check a stride of indices per tensor (full sweeps of the
    // projection weights would re-run the twin ~1500 times)
    for idx in (0..n * d).step_by(3) {
        let fd = central(&x64, idx,
                         |t| loss(t, &wq64, &wk64, &wv64, &wo64));
        check(&format!("mha dx[{idx}]"), d_x.data[idx], fd);
    }
    for idx in (0..heads * d * dh).step_by(7) {
        let fd = central(&wq64, idx,
                         |t| loss(&x64, t, &wk64, &wv64, &wo64));
        check(&format!("mha dwq[{idx}]"), grads.wq[idx], fd);
        let fd = central(&wk64, idx,
                         |t| loss(&x64, &wq64, t, &wv64, &wo64));
        check(&format!("mha dwk[{idx}]"), grads.wk[idx], fd);
        let fd = central(&wv64, idx,
                         |t| loss(&x64, &wq64, &wk64, t, &wo64));
        check(&format!("mha dwv[{idx}]"), grads.wv[idx], fd);
    }
    for idx in (0..d * d).step_by(5) {
        let fd = central(&wo64, idx,
                         |t| loss(&x64, &wq64, &wk64, &wv64, t));
        check(&format!("mha dwo[{idx}]"), grads.wo[idx], fd);
    }
}
