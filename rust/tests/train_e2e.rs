//! End-to-end acceptance tests for the deterministic CPU trainer:
//!
//! 1. Training is **bitwise reproducible across worker counts** — the
//!    same config at `workers = 1` and `workers = 4` yields identical
//!    step losses, epoch losses, and checkpoint bytes (every kernel
//!    reduction is sequential in index order; threads only change who
//!    computes, never what is summed in which order).
//! 2. The replayed-batch loop actually learns: epoch mean loss is
//!    strictly decreasing.
//! 3. A checkpoint written by the trainer serves through
//!    `weights`/`init = load`: two independent coordinators loading the
//!    same trained file answer bitwise-identically, and differently
//!    from the seeded function (the weights really moved).

use ssaformer::config::{InitPolicy, ServingConfig, Variant};
use ssaformer::coordinator::{Coordinator, ExecBackend};
use ssaformer::model::checkpoint;
use ssaformer::train::{train_cpu, CpuTrainConfig};
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "ssaformer-it-train-{}-{name}.ckpt", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Small non-serving dims: worker invariance is a property of the
/// kernels/trainer, not of the serving shape.
fn tiny(workers: usize) -> CpuTrainConfig {
    CpuTrainConfig {
        d_model: 16,
        n_heads: 2,
        ffn_mult: 2,
        layers: 3,
        vocab: 96,
        seq: 16,
        batch: 2,
        steps_per_epoch: 4,
        epochs: 2,
        seed: 11,
        corpus_lines: 60,
        workers,
        ..Default::default()
    }
}

#[test]
fn training_is_bitwise_identical_across_worker_counts() {
    let one = train_cpu(&tiny(1));
    let four = train_cpu(&tiny(4));

    assert_eq!(bits(&one.report.step_losses),
               bits(&four.report.step_losses),
               "step losses must not depend on the worker count");
    assert_eq!(bits(&one.report.epoch_losses),
               bits(&four.report.epoch_losses),
               "epoch losses must not depend on the worker count");

    let (p1, p4) = (tmp("w1"), tmp("w4"));
    checkpoint::save(&one.stack, &p1).unwrap();
    checkpoint::save(&four.stack, &p4).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p4).unwrap(),
               "checkpoints must be byte-identical across worker counts");
    std::fs::remove_file(&p1).unwrap();
    std::fs::remove_file(&p4).unwrap();

    assert!(one.report.epoch_loss_strictly_decreasing(),
            "epoch losses {:?} must strictly decrease on replayed batches",
            one.report.epoch_losses);
}

#[test]
fn trained_checkpoint_serves_through_init_load() {
    // serving dims are locked by `ExecBackend::cpu_from_config`
    // (d_model = 64, 4 heads, vocab 2048, seed 42) — the trainer's
    // defaults match them by design; only shrink the schedule here.
    let cfg = CpuTrainConfig {
        layers: 2,
        epochs: 1,
        steps_per_epoch: 2,
        batch: 2,
        corpus_lines: 80,
        ..Default::default()
    };
    let outcome = train_cpu(&cfg);
    let path = tmp("serve");
    checkpoint::save(&outcome.stack, &path).unwrap();

    let serve = |weights: Option<String>| -> Vec<f32> {
        let scfg = ServingConfig {
            artifacts_dir: "no/such/artifacts".into(),
            variant: Variant::Full,
            layers: cfg.layers,
            ffn_mult: cfg.ffn_mult,
            projections: true,
            init: if weights.is_some() { InitPolicy::Load }
                  else { InitPolicy::Seeded },
            weights,
            max_batch: 2,
            max_wait_ms: 2,
            queue_capacity: 32,
            workers: 1,
            cache_capacity: 0,
            ..Default::default()
        };
        scfg.validate().unwrap();
        let c = Arc::new(Coordinator::start(
            ExecBackend::auto(&scfg).unwrap(), &scfg).unwrap());
        let toks: Vec<i32> = (0..48).map(|i| 3 + (i * 23) % 2000).collect();
        c.submit_blocking(toks).unwrap().embedding.unwrap()
    };

    let w = Some(path.to_string_lossy().into_owned());
    let a = serve(w.clone());
    let b = serve(w);
    assert_eq!(bits(&a), bits(&b),
               "two coordinators loading the same trained checkpoint must \
                answer bitwise-identically");

    let seeded = serve(None);
    assert_ne!(bits(&a), bits(&seeded),
               "the trained function must differ from the seeded one");

    std::fs::remove_file(&path).unwrap();
}
