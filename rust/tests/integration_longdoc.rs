//! Streaming long-document ENCODE end-to-end: documents past the
//! largest bucket served over TCP through the chunked path, the
//! prefix-reuse cache (hit ≡ recompute **bitwise**, pinned against a
//! cold identically-configured server), per-document request
//! accounting, and the chunking-is-the-identity property for
//! sequences that fit a bucket.
//!
//! Runs unconditionally on the CPU backend (no artifacts needed) —
//! the same stack `tests/integration_cpu_serving.rs` exercises, plus
//! the `chunk_tokens` / `prefix_cache_capacity` knobs.

use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{
    merge_chunk_embeddings, Coordinator, CpuEngine, CpuModel,
    CpuModelConfig, ExecBackend,
};
use ssaformer::proptest_mini::{prop_assert, run};
use ssaformer::server::{serve, Client};
use std::sync::Arc;

/// Buckets [32, 64] with 32-token chunks: documents past 64 tokens
/// take the chunked path. Embedding cache off so every counter below
/// meters the prefix cache alone.
fn longdoc_config(chunk_tokens: usize, prefix_capacity: usize) -> ServingConfig {
    ServingConfig {
        variant: Variant::SpectralShift,
        max_batch: 4,
        max_wait_ms: 5,
        queue_capacity: 64,
        seq_buckets: vec![32, 64],
        workers: 2,
        cache_capacity: 0,
        chunk_tokens,
        prefix_cache_capacity: prefix_capacity,
        ..Default::default()
    }
}

fn start(cfg: &ServingConfig) -> Arc<Coordinator> {
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), cfg.variant)));
    Arc::new(Coordinator::start(ExecBackend::Cpu(engine), cfg).unwrap())
}

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 31 + seed) % 2000)).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn long_document_serves_over_tcp_and_equals_the_merged_chunks() {
    let c = start(&longdoc_config(32, 16));
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();

    // 160 tokens = 5 chunks of 32, 2.5× the largest bucket
    let doc = toks(160, 3);
    let reply = client.encode(7, &doc).unwrap();
    assert!(reply.starts_with("OK 7 "), "{reply}");
    let parts: Vec<&str> = reply.split_whitespace().collect();
    assert_eq!(parts.len(), 2 + 8, "{reply}");

    // cross-check against a chunking-free coordinator: encode each
    // 32-token chunk as a plain request (the identical compute path
    // the chunked coordinator uses internally) and merge
    let plain = start(&longdoc_config(0, 0));
    let chunk_parts: Vec<(usize, Arc<[f32]>)> = doc
        .chunks(32)
        .map(|ch| {
            let emb = plain.submit_blocking(ch.to_vec()).unwrap()
                .embedding.unwrap();
            (ch.len(), Arc::from(&emb[..]))
        })
        .collect();
    let want = merge_chunk_embeddings(&chunk_parts);
    for (j, p) in parts[2..].iter().enumerate() {
        assert_eq!(*p, format!("{:.5}", want[j]),
                   "dim {j} of the chunked reply diverged: {reply}");
    }

    // per-document accounting: one logical request, chunk work metered
    // on the prefix: line
    let m = &c.metrics;
    assert_eq!(m.requests_in.get(), 1);
    assert_eq!(m.requests_done.get(), 1);
    assert_eq!(m.prefix_misses.get(), 5);
    assert_eq!(m.chunks_computed.get(), 5);
    assert_eq!(m.prefix_hits.get(), 0);
    let stats = client.stats().unwrap();
    assert!(stats.contains("prefix:   hits=0 misses=5 chunks=5"), "{stats}");
    handle.stop();
}

#[test]
fn prefix_hits_are_bitwise_identical_to_a_cold_recompute() {
    // warm server: sees the template document, then a second document
    // sharing its first 4 chunks (4/5 = 80% chunk overlap)
    let warm = start(&longdoc_config(32, 16));
    let (waddr, whandle) = serve(warm.clone(), "127.0.0.1:0", 2).unwrap();
    // cold server: identically configured, sees only the second
    // document — every chunk computed from scratch
    let cold = start(&longdoc_config(32, 16));
    let (caddr, chandle) = serve(cold.clone(), "127.0.0.1:0", 2).unwrap();

    let template = toks(160, 11);
    let mut shared_tail = template[..128].to_vec();
    shared_tail.extend(toks(32, 999)); // distinct last chunk

    let mut wclient = Client::connect(&waddr).unwrap();
    let first = wclient.encode(1, &template).unwrap();
    assert!(first.starts_with("OK 1 "), "{first}");
    assert_eq!(warm.metrics.prefix_hits.get(), 0);

    // exact replay: every chunk a hit, reply byte-identical
    let replay = wclient.encode(1, &template).unwrap();
    assert_eq!(replay, first, "replayed document reply must be byte-equal");
    assert_eq!(warm.metrics.prefix_hits.get(), 5);
    assert_eq!(warm.metrics.chunks_computed.get(), 5, "hits recompute nothing");

    // overlapping document on the warm server vs the cold server:
    // 4 prefix hits + 1 computed tail must be byte-equal on the wire …
    let warm_reply = wclient.encode(2, &shared_tail).unwrap();
    let mut cclient = Client::connect(&caddr).unwrap();
    let cold_reply = cclient.encode(2, &shared_tail).unwrap();
    assert!(warm_reply.starts_with("OK 2 "), "{warm_reply}");
    assert_eq!(warm_reply, cold_reply,
               "prefix-cache hits changed the served embedding");
    assert_eq!(warm.metrics.prefix_hits.get(), 9); // 5 replay + 4 shared
    assert_eq!(warm.metrics.chunks_computed.get(), 6);

    // … and bitwise-identical at full precision, past the %.5f wire
    // (this in-process resubmit is fully resident: 5 more warm hits)
    let warm_emb = warm.submit_blocking(shared_tail.clone()).unwrap()
        .embedding.unwrap();
    let cold_emb = cold.submit_blocking(shared_tail).unwrap()
        .embedding.unwrap();
    assert_eq!(bits(&warm_emb), bits(&cold_emb),
               "hit must equal recompute bitwise");

    // 20 chunk lookups total, 14 hits — well past the ≥50%-overlap
    // workload the STATS line must surface
    let stats = wclient.stats().unwrap();
    assert!(stats.contains("prefix:   hits=14 misses=6 chunks=6 (70% hit rate)"),
            "{stats}");
    whandle.stop();
    chandle.stop();
}

#[test]
fn property_chunking_is_the_identity_for_sequences_that_fit() {
    // a sequence ≤ n_max with chunk_tokens ≥ len never takes the
    // chunked path, so enabling chunking must be bitwise invisible
    let chunked = start(&longdoc_config(64, 16));
    let plain = start(&longdoc_config(0, 0));
    run(12, |g| {
        let len = g.usize_in(1, 64);
        let seed = g.usize_in(0, 5000) as i32;
        let t = toks(len, seed);
        let a = chunked.submit_blocking(t.clone()).unwrap()
            .embedding.unwrap();
        let b = plain.submit_blocking(t).unwrap().embedding.unwrap();
        prop_assert(bits(&a) == bits(&b),
                    format!("len {len} seed {seed}: chunk-capable \
                             coordinator diverged from the plain path"))
    });
    assert_eq!(chunked.metrics.prefix_misses.get(), 0,
               "short sequences must never touch the prefix cache");
}

#[test]
fn disabled_chunking_still_rejects_and_expired_documents_count_once() {
    // chunk_tokens = 0 keeps the pre-chunking contract over the wire
    let c = start(&longdoc_config(0, 0));
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let reply = client.encode(3, &toks(160, 3)).unwrap();
    assert_eq!(reply, "ERR 3 too-long-160-max-64");
    handle.stop();

    // an already-expired deadline on a chunkable document: one expiry
    // for the whole document, no chunk ever admitted
    let c = start(&longdoc_config(32, 16));
    let (addr, handle) = serve(c.clone(), "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let reply = client.encode_with_deadline(4, &toks(160, 3), 0).unwrap();
    assert_eq!(reply, "ERR 4 deadline");
    assert_eq!(c.metrics.requests_expired.get(), 1);
    assert_eq!(c.metrics.prefix_misses.get() + c.metrics.prefix_hits.get(), 0);
    // the same document with a generous budget then serves normally
    let reply = client.encode_with_deadline(5, &toks(160, 3), 60_000).unwrap();
    assert!(reply.starts_with("OK 5 "), "{reply}");
    assert_eq!(c.metrics.requests_done.get(), 1);
    handle.stop();
}
