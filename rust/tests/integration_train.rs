//! Integration: train-step artifact smoke (a short run; the full loss
//! curve lives in examples/train_tiny.rs → EXPERIMENTS.md E10).

use ssaformer::config::Variant;
use ssaformer::runtime::Engine;
use ssaformer::train::{train, TrainConfig};

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new("artifacts").unwrap())
}

#[test]
fn five_steps_reduce_loss_ss() {
    let Some(e) = engine() else { return };
    let cfg = TrainConfig {
        variant: Variant::SpectralShift,
        steps: 5,
        seed: 3,
        corpus_lines: 300,
        log_every: 1,
    };
    let report = train(&e, &cfg).unwrap();
    assert_eq!(report.points.len(), 5);
    // initial loss ≈ ln(vocab) = ln 2048 ≈ 7.62
    assert!((report.initial_loss - 7.6).abs() < 0.6,
            "initial {}", report.initial_loss);
    assert!(report.final_loss < report.initial_loss,
            "loss did not move: {} -> {}", report.initial_loss, report.final_loss);
    assert!(report.points.iter().all(|p| p.loss.is_finite()));
    assert!(report.tokens_per_sec > 0.0);
}

#[test]
fn training_is_deterministic_per_seed() {
    let Some(e) = engine() else { return };
    let cfg = TrainConfig {
        variant: Variant::SpectralShift,
        steps: 2,
        seed: 11,
        corpus_lines: 200,
        log_every: 1,
    };
    let a = train(&e, &cfg).unwrap();
    let b = train(&e, &cfg).unwrap();
    assert_eq!(a.points[1].loss, b.points[1].loss);
}

#[test]
fn missing_variant_errors() {
    let Some(e) = engine() else { return };
    // nystrom train artifact is intentionally not emitted
    let cfg = TrainConfig { variant: Variant::Nystrom, steps: 1, ..Default::default() };
    assert!(train(&e, &cfg).is_err());
}
