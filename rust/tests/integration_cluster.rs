//! Cluster-tier fault-injection suite: router front-end + N replica
//! serving processes over real loopback sockets, with replica failures
//! injected deterministically through the
//! [`FaultPlan`](ssaformer::server::FaultPlan) seam.
//!
//! Every scenario is deterministic modulo ephemeral port numbers: the
//! tests rebuild the router's own [`HashRing`] at runtime to *predict*
//! request placement instead of hoping traffic spreads, fault selection
//! is pure arithmetic over accept order, and membership transitions are
//! driven by explicit `probe_now()` sweeps rather than timers. The
//! driver runs this suite three times in a row — nothing here may
//! depend on wall-clock luck.
//!
//! The two acceptance pins from the cluster tier:
//! * a replica killed mid-batch loses **zero** accepted requests (each
//!   is retried on a live replica or answered `ERR replica-lost`);
//! * 1 router + 1 replica answers **byte-identically** to today's
//!   single-process server.

use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::cluster::{
    hash_tokens, serve_router, ClusterConfig, ClusterRouter, HashRing,
    RouterHandle, DEFAULT_VNODES,
};
use ssaformer::coordinator::{
    Coordinator, CpuEngine, CpuModel, CpuModelConfig, ExecBackend,
};
use ssaformer::server::{serve_with_faults, Client, FaultPlan, ServerHandle};
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn replica_config() -> ServingConfig {
    ServingConfig {
        variant: Variant::SpectralShift,
        max_batch: 4,
        max_wait_ms: 5,
        queue_capacity: 64,
        cache_capacity: 64,
        ..Default::default()
    }
}

fn start_replica_with(cfg: &ServingConfig, bind: &str,
                      faults: Option<FaultPlan>)
                      -> (Arc<Coordinator>, SocketAddr, ServerHandle) {
    let engine = Box::new(CpuEngine::new(CpuModel::new(
        CpuModelConfig::default(), cfg.variant)));
    let c = Arc::new(Coordinator::start(ExecBackend::Cpu(engine), cfg).unwrap());
    let (addr, h) = serve_with_faults(c.clone(), bind, 4, faults).unwrap();
    (c, addr, h)
}

fn start_replica() -> (Arc<Coordinator>, SocketAddr, ServerHandle) {
    start_replica_with(&replica_config(), "127.0.0.1:0", None)
}

/// Router over the given replica addresses: long probe interval (tests
/// drive probes explicitly via `probe_now()`), short connect timeout so
/// dead-replica scenarios fail over quickly.
fn router_over(addrs: &[SocketAddr], cache_capacity: usize)
               -> (Arc<ClusterRouter>, SocketAddr, RouterHandle) {
    let cfg = ClusterConfig {
        replicas: addrs.iter().map(|a| a.to_string()).collect(),
        probe_interval: Duration::from_secs(600),
        cache_capacity,
        connect_timeout: Duration::from_millis(500),
        reply_timeout: Duration::from_secs(10),
        ..Default::default()
    };
    let r = Arc::new(ClusterRouter::new(cfg));
    let (addr, h) = serve_router(r.clone(), "127.0.0.1:0", 4).unwrap();
    (r, addr, h)
}

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 31 + seed) % 2000)).collect()
}

/// The ring the router itself builds, reconstructed so tests can
/// predict placement (determinism invariant: same inputs, same ring,
/// in any process).
fn ring_for(addrs: &[SocketAddr]) -> HashRing {
    let names: Vec<String> = addrs.iter().map(|a| a.to_string()).collect();
    HashRing::build(&names, DEFAULT_VNODES)
}

/// A token sequence of length `len` that the ring assigns to `target`.
fn toks_assigned_to(ring: &HashRing, target: usize, len: usize,
                    salt: i32) -> Vec<i32> {
    for seed in 0..10_000 {
        let t = toks(len, salt + seed * 7919);
        if ring.assign(hash_tokens(&t)) == target {
            return t;
        }
    }
    panic!("no length-{len} sequence assigned to replica {target}");
}

#[test]
fn single_replica_router_is_byte_identical_to_direct_serving() {
    // the degenerate cluster: 1 router in front of 1 replica must be
    // observationally today's single-process server, byte for byte
    let (replica, raddr, rhandle) = start_replica();
    let (router, addr, handle) = router_over(&[raddr], 64);

    let mut direct = Client::connect(&raddr).unwrap();
    let mut routed = Client::connect(&addr).unwrap();
    for (id, len) in [(1u64, 40usize), (2, 100), (3, 128), (4, 300)] {
        let t = toks(len, len as i32);
        // ask the replica directly first (computes + caches), then via
        // the router (forwards; the replica serves its cache hit —
        // bitwise a recompute, so the strings must match exactly)
        let want = direct.encode(id, &t).unwrap();
        let got = routed.encode(id, &t).unwrap();
        assert_eq!(got, want, "router hop changed bytes for len {len}");
        assert!(got.starts_with(&format!("OK {id} ")), "{got}");
    }
    // and the reverse order: a fresh sequence routed first, direct
    // second, must also agree (placement-independent determinism)
    let t = toks(260, 9);
    let via_router = routed.encode(5, &t).unwrap();
    let via_direct = direct.encode(5, &t).unwrap();
    assert_eq!(via_router, via_direct);

    // drain/handoff accounting: everything forwarded, nothing lost
    assert_eq!(router.metrics.forwarded.get(), 5);
    assert_eq!(router.metrics.replica_lost.get(), 0);
    assert_eq!(router.metrics.retried.get(), 0);
    assert_eq!(replica.metrics.requests_done.get(), 10); // 5 direct + 5 routed
    handle.stop();
    rhandle.stop();
}

#[test]
fn router_spreads_load_across_replicas_by_ring_assignment() {
    let (ra, aaddr, ahandle) = start_replica();
    let (rb, baddr, bhandle) = start_replica();
    let (router, addr, handle) = router_over(&[aaddr, baddr], 0);
    let ring = ring_for(&[aaddr, baddr]);

    // 3 sequences pinned to each replica by the ring — placement is
    // predicted, not hoped for
    let mut client = Client::connect(&addr).unwrap();
    let mut id = 0u64;
    for target in [0usize, 1] {
        for k in 0..3 {
            let t = toks_assigned_to(&ring, target, 64 + 4 * k, k as i32);
            id += 1;
            let reply = client.encode(id, &t).unwrap();
            assert!(reply.starts_with(&format!("OK {id} ")), "{reply}");
        }
    }
    // each replica executed exactly its ring share
    assert_eq!(ra.metrics.requests_in.get(), 3, "replica A share");
    assert_eq!(rb.metrics.requests_in.get(), 3, "replica B share");
    assert_eq!(router.metrics.forwarded.get(), 6);
    assert_eq!(router.metrics.replica_lost.get(), 0);

    // router STATS reports the cluster shape and the counters
    let stats = client.stats().unwrap();
    assert!(stats.contains("role:     router"), "{stats}");
    assert!(stats.contains("replicas=2 up=2 down=0"), "{stats}");
    assert!(stats.contains("forwarded=6"), "{stats}");
    assert!(stats.contains(&aaddr.to_string()), "{stats}");
    handle.stop();
    ahandle.stop();
    bhandle.stop();
}

#[test]
fn killed_replica_mid_batch_loses_zero_accepted_requests() {
    // replica B hard-closes every connection after 5 reply bytes — a
    // replica dying mid-batch, deterministically, on every attempt.
    // Every request the router accepted must still be answered OK
    // (failed over to A) — zero lost, zero silently dropped.
    let (ra, aaddr, ahandle) = start_replica();
    let kill = FaultPlan {
        drop_after_bytes: Some(5),
        every_nth: 0, // every connection
        ..Default::default()
    };
    let (rb, baddr, bhandle) =
        start_replica_with(&replica_config(), "127.0.0.1:0", Some(kill));
    let (router, addr, handle) = router_over(&[aaddr, baddr], 0);
    let ring = ring_for(&[aaddr, baddr]);

    let mut client = Client::connect(&addr).unwrap();
    let mut oks = 0;
    for k in 0..4u64 {
        // all four pinned to the dying replica B — the worst case
        let t = toks_assigned_to(&ring, 1, 72 + 4 * k as usize, k as i32);
        let reply = client.encode(k, &t).unwrap();
        assert!(reply.starts_with(&format!("OK {k} ")),
                "request {k} was lost: {reply}");
        oks += 1;
    }
    assert_eq!(oks, 4);
    // accounting identity: accepted = answered + lost, lost = 0
    assert_eq!(router.metrics.forwarded.get(), 4);
    assert_eq!(router.metrics.replica_lost.get(), 0);
    // B's failures forced failovers: at least the first request paid a
    // retry onto A, and B is marked down afterwards
    assert!(router.metrics.retried.get() >= 1,
            "no failover recorded: {}", router.metrics.retried.get());
    assert!(!router.membership().is_up(1), "dying replica still up");
    // A answered everything; B may have *executed* requests (its
    // replies were truncated) — at-least-once is explicitly fine
    assert_eq!(ra.metrics.requests_done.get(), 4);
    let _ = rb;
    handle.stop();
    ahandle.stop();
    bhandle.stop();
}

#[test]
fn all_replicas_lost_is_err_replica_lost_not_a_hang_or_drop() {
    let (_ra, aaddr, ahandle) = start_replica();
    let (_rb, baddr, bhandle) = start_replica();
    // replicas are gone before the router ever forwards
    ahandle.stop();
    bhandle.stop();
    let (router, addr, handle) = router_over(&[aaddr, baddr], 0);

    let mut client = Client::connect(&addr).unwrap();
    let t0 = Instant::now();
    let reply = client.encode(7, &toks(64, 1)).unwrap();
    assert_eq!(reply, "ERR 7 replica-lost");
    // fail-fast, not a hang: both replicas refuse connections
    // immediately on loopback
    assert!(t0.elapsed() < Duration::from_secs(8), "{:?}", t0.elapsed());
    assert_eq!(router.metrics.replica_lost.get(), 1);
    assert_eq!(router.metrics.forwarded.get(), 1);
    // both replicas were marked down by the failed attempts
    assert_eq!(router.membership().up_count(), 0);
    handle.stop();
}

#[test]
fn slow_replica_delivers_late_reply_through_the_router() {
    // a slow replica (300ms before every reply byte) must yield a
    // *late OK*, never a drop: executing requests are not aborted, and
    // the router's reply timeout (10s) passes the late answer through
    let slow = FaultPlan {
        response_delay: Some(Duration::from_millis(300)),
        every_nth: 0,
        ..Default::default()
    };
    let (_r, raddr, rhandle) =
        start_replica_with(&replica_config(), "127.0.0.1:0", Some(slow));
    let (router, addr, handle) = router_over(&[raddr], 0);

    let mut client = Client::connect(&addr).unwrap();
    let t0 = Instant::now();
    let reply = client.encode(3, &toks(100, 2)).unwrap();
    let elapsed = t0.elapsed();
    assert!(reply.starts_with("OK 3 "), "{reply}");
    assert!(elapsed >= Duration::from_millis(300),
            "delay fault did not fire: {elapsed:?}");
    assert_eq!(router.metrics.replica_lost.get(), 0);

    // slow replica vs deadline: the budget (100ms) covers admission and
    // queueing, which succeed long before it expires; the *write* delay
    // lands after execution, so the contract is a late OK — an
    // executing request is never aborted, and the router passes the
    // late answer through instead of fabricating a drop
    let t0 = Instant::now();
    let reply = client
        .encode_with_deadline(4, &toks(80, 5), 100)
        .unwrap();
    assert!(reply.starts_with("OK 4 "), "{reply}");
    assert!(t0.elapsed() >= Duration::from_millis(300));
    assert_eq!(router.metrics.expired_at_router.get(), 0);
    assert_eq!(router.metrics.replica_lost.get(), 0);
    handle.stop();
    rhandle.stop();
}

#[test]
fn deadline_propagates_through_the_router_hop() {
    // replica that holds requests for batchmates far longer than any
    // deadline: if the router forwards DEADLINE_MS, the replica's own
    // deadline machinery fires; if the router dropped the field, the
    // request would be held ~30s and come back OK
    let hold = ServingConfig {
        max_wait_ms: 30_000,
        deadline_margin_ms: 0,
        ..replica_config()
    };
    let (replica, raddr, rhandle) =
        start_replica_with(&hold, "127.0.0.1:0", None);
    let (router, addr, handle) = router_over(&[raddr], 0);
    let mut client = Client::connect(&addr).unwrap();

    // (a) expired at the router: zero budget never touches a replica
    let reply = client.encode_with_deadline(11, &toks(64, 3), 0).unwrap();
    assert_eq!(reply, "ERR 11 deadline");
    assert_eq!(router.metrics.expired_at_router.get(), 1);
    assert_eq!(replica.metrics.requests_in.get(), 0,
               "expired-at-router request reached a replica");

    // (b) live budget is forwarded and expires *at the replica* while
    // queued — proof the DEADLINE_MS field survived the hop
    let t0 = Instant::now();
    let reply = client.encode_with_deadline(12, &toks(64, 3), 300).unwrap();
    let elapsed = t0.elapsed();
    assert_eq!(reply, "ERR 12 deadline");
    assert_eq!(replica.metrics.requests_expired.get(), 1,
               "replica never saw the forwarded deadline");
    assert!(elapsed < Duration::from_secs(20),
            "deadline did not propagate — request was held: {elapsed:?}");
    assert_eq!(router.metrics.expired_at_router.get(), 1, "(a) only");

    // (c) a generous budget serves normally end to end
    let reply = client
        .encode_with_deadline(13, &toks(128, 3), 60_000)
        .unwrap();
    assert!(reply.starts_with("OK 13 "), "{reply}");
    assert_eq!(router.metrics.forwarded.get(), 2); // (b) and (c)
    handle.stop();
    rhandle.stop();
}

#[test]
fn router_restart_preserves_placement_and_replies() {
    let (_replica, raddr, rhandle) = start_replica();
    let t = toks(200, 4);

    let (_r1, addr1, handle1) = router_over(&[raddr], 64);
    let before = Client::connect(&addr1).unwrap().encode(21, &t).unwrap();
    assert!(before.starts_with("OK 21 "), "{before}");
    handle1.stop(); // router process "crashes"

    // a fresh router over the same replica set rebuilds the identical
    // ring (deterministic placement) and serves byte-identical replies
    let (_r2, addr2, handle2) = router_over(&[raddr], 64);
    let after = Client::connect(&addr2).unwrap().encode(21, &t).unwrap();
    assert_eq!(after, before, "restart changed served bytes");
    handle2.stop();
    rhandle.stop();
}

#[test]
fn router_cache_hit_is_bitwise_a_recompute_and_skips_replicas() {
    let (replica, raddr, rhandle) = start_replica();
    let (router, addr, handle) = router_over(&[raddr], 64);
    let mut client = Client::connect(&addr).unwrap();

    let t = toks(128, 8);
    let first = client.encode(31, &t).unwrap();
    assert!(first.starts_with("OK 31 "), "{first}");
    assert_eq!(replica.metrics.requests_in.get(), 1);

    // identical tokens: served from the router cache — byte-equal
    // payload, and the replica is never consulted
    let second = client.encode(31, &t).unwrap();
    assert_eq!(second, first, "cache hit diverged from recompute");
    assert_eq!(router.metrics.cache_hits.get(), 1);
    assert_eq!(replica.metrics.requests_in.get(), 1,
               "cache hit still reached the replica");
    assert_eq!(router.cache_len(), 1);

    // cross-check against the replica's own serving of the same tokens:
    // a hit anywhere is bitwise a recompute anywhere
    let direct = Client::connect(&raddr).unwrap().encode(31, &t).unwrap();
    assert_eq!(direct, first);
    handle.stop();
    rhandle.stop();
}

#[test]
fn probes_mark_replicas_down_and_recover_them() {
    let (_ra, aaddr, ahandle) = start_replica();
    let (_rb, baddr, bhandle) = start_replica();
    let (router, addr, handle) = router_over(&[aaddr, baddr], 0);

    router.probe_now();
    assert_eq!(router.membership().up_count(), 2);
    assert_eq!(router.metrics.probe_failures.get(), 0);

    // replica B dies; the next sweep notices
    bhandle.stop();
    router.probe_now();
    assert_eq!(router.membership().up_count(), 1);
    assert!(!router.membership().is_up(1));
    assert!(router.metrics.probe_failures.get() >= 1);

    // traffic keeps flowing to the survivor — even sequences the ring
    // assigns to B fail over to A
    let ring = ring_for(&[aaddr, baddr]);
    let t = toks_assigned_to(&ring, 1, 64, 5);
    let mut client = Client::connect(&addr).unwrap();
    let reply = client.encode(41, &t).unwrap();
    assert!(reply.starts_with("OK 41 "), "{reply}");
    assert_eq!(router.metrics.replica_lost.get(), 0);

    // B comes back on its exact old address; a sweep recovers it
    let cfg = replica_config();
    let (_rb2, baddr2, bhandle2) =
        start_replica_with(&cfg, &baddr.to_string(), None);
    assert_eq!(baddr2, baddr, "rebind must reuse the advertised address");
    router.probe_now();
    assert_eq!(router.membership().up_count(), 2);
    assert!(router.membership().is_up(1));
    handle.stop();
    ahandle.stop();
    bhandle2.stop();
}

#[test]
fn refused_accept_fault_fails_over_like_a_dead_replica() {
    // replica B accepts TCP connections and instantly closes them (up
    // but not serving) — the router must treat it like any other loss
    let refuse = FaultPlan {
        refuse_accept: true,
        every_nth: 0,
        ..Default::default()
    };
    let (ra, aaddr, ahandle) = start_replica();
    let (_rb, baddr, bhandle) =
        start_replica_with(&replica_config(), "127.0.0.1:0", Some(refuse));
    let (router, addr, handle) = router_over(&[aaddr, baddr], 0);
    let ring = ring_for(&[aaddr, baddr]);

    let t = toks_assigned_to(&ring, 1, 96, 6);
    let mut client = Client::connect(&addr).unwrap();
    let reply = client.encode(51, &t).unwrap();
    assert!(reply.starts_with("OK 51 "), "{reply}");
    assert_eq!(ra.metrics.requests_done.get(), 1);
    assert_eq!(router.metrics.replica_lost.get(), 0);
    assert!(!router.membership().is_up(1));
    handle.stop();
    ahandle.stop();
    bhandle.stop();
}
