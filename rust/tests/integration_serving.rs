//! Integration: full serving stack (coordinator + TCP server) over real
//! artifacts. Skips when artifacts/ is missing.

use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{Coordinator, EncodeRequest, ExecBackend,
                             SubmitError};
use ssaformer::runtime::Engine;
use ssaformer::server::{serve, Client};
use std::sync::Arc;

fn setup(variant: Variant) -> Option<Arc<Coordinator>> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    let engine = Arc::new(Engine::new("artifacts").unwrap());
    let cfg = ServingConfig {
        variant,
        max_batch: 4,
        max_wait_ms: 5,
        queue_capacity: 64,
        workers: 2,
        queue_shards: 2,
        cache_capacity: 32,
        ..Default::default()
    };
    Some(Arc::new(
        Coordinator::start(ExecBackend::Xla(engine), &cfg).unwrap()))
}

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 31 + seed) % 2000)).collect()
}

#[test]
fn single_request_roundtrip() {
    let Some(c) = setup(Variant::SpectralShift) else { return };
    let resp = c.submit_blocking(toks(100, 1)).unwrap();
    let emb = resp.embedding.unwrap();
    assert!(!emb.is_empty());
    assert!(emb.iter().all(|x| x.is_finite()));
    assert_eq!(c.metrics.requests_done.get(), 1);
}

#[test]
fn batching_fills_up() {
    let Some(c) = setup(Variant::SpectralShift) else { return };
    // 8 concurrent same-bucket requests with a 4-slot batch → ≥... ≤ 4 batches
    let mut rxs = Vec::new();
    for i in 0..8 {
        rxs.push(c.submit(toks(100 + i, i as i32)).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.embedding.is_ok());
    }
    let batches = c.metrics.batches_executed.get();
    assert!(batches >= 2 && batches <= 8, "batches={batches}");
    assert_eq!(c.metrics.requests_done.get(), 8);
    // average fill > 1 proves batching actually happened
    assert!(c.metrics.requests_done.get() > batches);
}

#[test]
fn routes_to_larger_bucket() {
    let Some(c) = setup(Variant::SpectralShift) else { return };
    let resp = c.submit_blocking(toks(300, 2)).unwrap(); // needs n=512 bucket
    resp.embedding.expect("512-bucket encode");
    let too_long = c.submit_blocking(toks(2000, 3));
    assert!(matches!(too_long, Err(SubmitError::TooLong { .. })));
    assert!(matches!(c.submit_blocking(vec![]), Err(SubmitError::Empty)));
}

#[test]
fn variants_serve_distinct_embeddings() {
    let Some(c_full) = setup(Variant::Full) else { return };
    let Some(c_ss) = setup(Variant::SpectralShift) else { return };
    let t = toks(64, 4);
    let e_full = c_full.submit_blocking(t.clone()).unwrap().embedding.unwrap();
    let e_ss = c_ss.submit_blocking(t).unwrap().embedding.unwrap();
    assert_eq!(e_full.len(), e_ss.len());
    assert_ne!(e_full, e_ss, "approximation must differ from exact");
    // but stay correlated
    let dot: f32 = e_full.iter().zip(&e_ss).map(|(a, b)| a * b).sum();
    let na: f32 = e_full.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = e_ss.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!(dot / (na * nb) > 0.5, "cosine {}", dot / (na * nb));
}

#[test]
fn tcp_server_end_to_end() {
    let Some(c) = setup(Variant::SpectralShift) else { return };
    let (addr, handle) = serve(c, "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    let reply = client.encode(42, &toks(50, 5)).unwrap();
    assert!(reply.starts_with("OK 42 "), "{reply}");
    let parts: Vec<&str> = reply.split_whitespace().collect();
    assert_eq!(parts.len(), 2 + 8); // OK id + 8 dims
    let stats = client.stats().unwrap();
    assert!(stats.contains("requests"), "{stats}");
    handle.stop();
}

#[test]
fn tcp_server_error_paths() {
    let Some(c) = setup(Variant::SpectralShift) else { return };
    let (addr, handle) = serve(c, "127.0.0.1:0", 2).unwrap();
    let mut client = Client::connect(&addr).unwrap();
    // too-long request
    let reply = client.encode(1, &toks(3000, 6)).unwrap();
    assert!(reply.starts_with("ERR 1 too-long"), "{reply}");
    handle.stop();
}

#[test]
fn xla_backend_caches_and_honors_deadlines() {
    // cache + deadline semantics are backend-agnostic: the XLA pool
    // must behave exactly like the CPU pool does in
    // integration_cpu_serving.rs
    let Some(c) = setup(Variant::SpectralShift) else { return };
    let t = toks(90, 8);
    let first = c.submit_blocking(t.clone()).unwrap().embedding.unwrap();
    let again = c.submit_blocking(t.clone()).unwrap().embedding.unwrap();
    assert_eq!(first, again, "cache hit must equal the computed embedding");
    assert!(c.metrics.cache_hits.get() >= 1);
    // an already-expired deadline is rejected without a batch slot
    let slots = c.metrics.batch_slots.get();
    let err = c.submit(EncodeRequest::new(toks(91, 9))
        .deadline(std::time::Duration::ZERO));
    assert!(matches!(err, Err(SubmitError::DeadlineExpired)));
    assert_eq!(c.metrics.batch_slots.get(), slots);
    assert_eq!(c.metrics.requests_expired.get(), 1);
}

#[test]
fn graceful_shutdown_drains() {
    let Some(c) = setup(Variant::SpectralShift) else { return };
    let rx = c.submit(toks(80, 7)).unwrap();
    let c = Arc::try_unwrap(c).ok().expect("sole owner");
    c.shutdown();
    // queued request still answered before shutdown completed
    let resp = rx.recv().unwrap();
    assert!(resp.embedding.is_ok());
}
