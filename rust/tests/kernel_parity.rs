//! Parity + determinism property tests for the `kernels::` fast path
//! against the naive reference kernels (`attention::matmul_f32` and the
//! seed implementations in `attention::spectral_shift::reference`).
//!
//! Invariants:
//! * max rel err < 1e-4 between fast and reference across odd shapes
//!   (non-multiples of the 32-row block / register-tile heights, 1×1,
//!   tall-skinny, wide-flat) — on **every** micro-kernel arm this host
//!   can run ([`Isa::available`]), not just the default one,
//! * 1-thread and N-thread results are **bitwise identical** (fixed
//!   per-row reduction order), again per arm.

use ssaformer::attention::spectral_shift::{reference, SpectralShiftConfig};
use ssaformer::attention::{matmul_f32, nystrom_attention_with, Tensor2};
use ssaformer::attention::spectral_shift_attention_with;
use ssaformer::kernels::{
    attention_batched, flash_attention, gemm_f32, layernorm, softmax_gemm,
    transpose_into, BatchedAttention, BatchedVariant, Isa, KernelCtx, Workspace,
};
use ssaformer::linalg::row_softmax_f32;
use ssaformer::minirt::ThreadPool;
use ssaformer::proptest_mini::{prop_assert, run};
use ssaformer::rngx::Rng;
use std::sync::Arc;

fn max_rel_err(got: &Tensor2, want: &Tensor2) -> f32 {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols));
    let mut denom = 0.0f32;
    for x in &want.data {
        denom = denom.max(x.abs());
    }
    got.max_abs_diff(want) / denom.max(1e-6)
}

/// Materialized softmax-GEMM reference built from the naive kernels.
fn softmax_gemm_ref(q: &Tensor2, kt: &Tensor2, x: &Tensor2, scale: f32) -> Tensor2 {
    let mut ktt = Tensor2::zeros(kt.cols, kt.rows);
    transpose_into(&kt.data, &mut ktt.data, kt.rows, kt.cols);
    let mut f = matmul_f32(q, &ktt);
    for s in f.data.iter_mut() {
        *s *= scale;
    }
    row_softmax_f32(&mut f.data, f.rows, f.cols);
    matmul_f32(&f, x)
}

#[test]
fn gemm_parity_property() {
    let ctx = KernelCtx::global();
    let mut ws = Workspace::new();
    run(60, |g| {
        let m = g.usize_in(1, 80);
        let k = g.usize_in(1, 70);
        let n = g.usize_in(1, 60);
        let mut rng = Rng::new((m * 10007 + k * 101 + n) as u64);
        let a = Tensor2::randn(&mut rng, m, k, 1.0);
        let b = Tensor2::randn(&mut rng, k, n, 1.0);
        let fast = gemm_f32(&ctx, &a, &b, &mut ws);
        let slow = matmul_f32(&a, &b);
        let err = max_rel_err(&fast, &slow);
        ws.put(fast.data);
        prop_assert(err < 1e-4, format!("({m},{k},{n}): rel err {err}"))
    });
}

#[test]
fn gemm_parity_extreme_shapes() {
    let ctx = KernelCtx::global();
    let mut ws = Workspace::new();
    // 1×1, tall-skinny, wide-flat, exact block multiples and off-by-one
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (1, 512, 1), (512, 1, 1),
                        (1, 1, 512), (1000, 3, 2), (2, 3, 1000),
                        (32, 256, 32), (33, 257, 31), (64, 64, 64)] {
        let mut rng = Rng::new((m + k * 7 + n * 13) as u64);
        let a = Tensor2::randn(&mut rng, m, k, 1.0);
        let b = Tensor2::randn(&mut rng, k, n, 1.0);
        let fast = gemm_f32(&ctx, &a, &b, &mut ws);
        let slow = matmul_f32(&a, &b);
        let err = max_rel_err(&fast, &slow);
        assert!(err < 1e-4, "({m},{k},{n}): rel err {err}");
        ws.put(fast.data);
    }
}

#[test]
fn softmax_gemm_parity_property() {
    let ctx = KernelCtx::global();
    let mut ws = Workspace::new();
    run(40, |g| {
        let n = g.usize_in(1, 90);
        let d = g.usize_in(1, 24);
        let c = g.usize_in(1, 24);
        let dv = g.usize_in(1, 24);
        let mut rng = Rng::new((n * 31 + d * 7 + c * 3 + dv) as u64);
        let q = Tensor2::randn(&mut rng, n, d, 1.0);
        let kt = Tensor2::randn(&mut rng, c, d, 1.0);
        let x = Tensor2::randn(&mut rng, c, dv, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        let fast = softmax_gemm(&ctx, &q, &kt, &x, scale, &mut ws);
        let slow = softmax_gemm_ref(&q, &kt, &x, scale);
        let err = max_rel_err(&fast, &slow);
        ws.put(fast.data);
        prop_assert(err < 1e-4, format!("({n},{d},{c},{dv}): rel err {err}"))
    });
}

#[test]
fn spectral_shift_fast_matches_seed_reference() {
    for &(n, c, d) in &[(64usize, 8usize, 8usize), (128, 16, 16), (256, 64, 32)] {
        let mut rng = Rng::new(n as u64);
        let q = Tensor2::randn(&mut rng, n, d, 1.0);
        let k = Tensor2::randn(&mut rng, n, d, 1.0);
        let v = Tensor2::randn(&mut rng, n, d, 1.0);
        let cfg = SpectralShiftConfig::new(c);
        let mut ws = Workspace::new();
        let fast = spectral_shift_attention_with(&q, &k, &v, &cfg,
                                                 &KernelCtx::global(), &mut ws);
        let seed = reference::spectral_shift_attention_ref(&q, &k, &v, &cfg);
        let err = max_rel_err(&fast, &seed);
        assert!(err < 1e-4, "(n={n},c={c},d={d}): rel err {err}");
    }
}

#[test]
fn nystrom_fast_matches_seed_reference() {
    let mut rng = Rng::new(77);
    let q = Tensor2::randn(&mut rng, 192, 16, 1.0);
    let k = Tensor2::randn(&mut rng, 192, 16, 1.0);
    let v = Tensor2::randn(&mut rng, 192, 16, 1.0);
    let mut ws = Workspace::new();
    let fast = nystrom_attention_with(&q, &k, &v, 16, 8, None,
                                      &KernelCtx::global(), &mut ws);
    let seed = reference::nystrom_attention_ref(&q, &k, &v, 16, 8, None);
    let err = max_rel_err(&fast, &seed);
    assert!(err < 1e-4, "rel err {err}");
}

#[test]
fn one_and_n_threads_bitwise_identical() {
    // explicit 1-worker and 4-worker pools, plus the pure-sequential
    // context: all three must produce byte-identical outputs
    let pool1 = Arc::new(ThreadPool::new(1));
    let pool4 = Arc::new(ThreadPool::new(4));
    let ctxs = [
        KernelCtx::sequential(),
        KernelCtx::with_pool(pool1),
        KernelCtx::with_pool(pool4),
    ];
    let mut rng = Rng::new(5);
    let q = Tensor2::randn(&mut rng, 160, 16, 1.0);
    let k = Tensor2::randn(&mut rng, 160, 16, 1.0);
    let v = Tensor2::randn(&mut rng, 160, 16, 1.0);
    let cfg = SpectralShiftConfig::new(16);

    let mut gemm_outs = Vec::new();
    let mut flash_outs = Vec::new();
    let mut ss_outs = Vec::new();
    for ctx in &ctxs {
        let mut ws = Workspace::new();
        gemm_outs.push(gemm_f32(ctx, &q, &k_t(&k), &mut ws).data);
        flash_outs.push(flash_attention(ctx, &q, &k, &v, 0.25, &mut ws).data);
        ss_outs.push(spectral_shift_attention_with(&q, &k, &v, &cfg, ctx, &mut ws).data);
    }
    for i in 1..ctxs.len() {
        assert_eq!(gemm_outs[0], gemm_outs[i], "gemm differs at ctx {i}");
        assert_eq!(flash_outs[0], flash_outs[i], "flash differs at ctx {i}");
        assert_eq!(ss_outs[0], ss_outs[i], "spectral shift differs at ctx {i}");
    }
}

fn k_t(k: &Tensor2) -> Tensor2 {
    let mut kt = Tensor2::zeros(k.cols, k.rows);
    transpose_into(&k.data, &mut kt.data, k.rows, k.cols);
    kt
}

#[test]
fn every_available_arm_matches_the_naive_gemm() {
    // the per-arm parity suite: each arm the host can run (scalar is
    // always one; avx2/neon when detected) vs the naive reference on
    // odd and degenerate shapes — off-by-one around the 8-lane vector
    // extent and the 8/4-row register tiles included
    let mut ws = Workspace::new();
    for isa in Isa::available() {
        let ctx = KernelCtx::global().with_isa(isa);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (1, 512, 1),
                            (7, 9, 7), (8, 8, 8), (9, 17, 9),
                            (33, 257, 31), (40, 300, 129), (64, 64, 64),
                            (1000, 3, 2)] {
            let mut rng = Rng::new((m + k * 7 + n * 13) as u64);
            let a = Tensor2::randn(&mut rng, m, k, 1.0);
            let b = Tensor2::randn(&mut rng, k, n, 1.0);
            let fast = gemm_f32(&ctx, &a, &b, &mut ws);
            let slow = matmul_f32(&a, &b);
            let err = max_rel_err(&fast, &slow);
            assert!(err < 1e-4, "{} ({m},{k},{n}): rel err {err}", isa.token());
            ws.put(fast.data);
        }
    }
}

#[test]
fn every_available_arm_is_thread_count_bitwise_deterministic() {
    // the within-arm determinism contract: for EACH arm, sequential /
    // 1-worker / 4-worker contexts produce byte-identical gemm, flash,
    // layernorm, and spectral-shift outputs
    let pool1 = Arc::new(ThreadPool::new(1));
    let pool4 = Arc::new(ThreadPool::new(4));
    let mut rng = Rng::new(21);
    let q = Tensor2::randn(&mut rng, 160, 16, 1.0);
    let k = Tensor2::randn(&mut rng, 160, 16, 1.0);
    let v = Tensor2::randn(&mut rng, 160, 16, 1.0);
    let mut gain = vec![0.0f32; 16];
    let mut bias = vec![0.0f32; 16];
    rng.fill_normal_f32(&mut gain, 1.0, 0.1);
    rng.fill_normal_f32(&mut bias, 0.0, 0.1);
    let cfg = SpectralShiftConfig::new(16);
    for isa in Isa::available() {
        let ctxs = [
            KernelCtx::sequential().with_isa(isa),
            KernelCtx::with_pool(pool1.clone()).with_isa(isa),
            KernelCtx::with_pool(pool4.clone()).with_isa(isa),
        ];
        let mut outs: Vec<[Vec<f32>; 4]> = Vec::new();
        for ctx in &ctxs {
            let mut ws = Workspace::new();
            outs.push([
                gemm_f32(ctx, &q, &k_t(&k), &mut ws).data,
                flash_attention(ctx, &q, &k, &v, 0.25, &mut ws).data,
                layernorm(ctx, &q, &gain, &bias, 1e-5, &mut ws).data,
                spectral_shift_attention_with(&q, &k, &v, &cfg, ctx,
                                              &mut ws).data,
            ]);
        }
        for i in 1..ctxs.len() {
            for (j, name) in ["gemm", "flash", "layernorm", "ss"]
                .iter().enumerate() {
                assert_eq!(outs[0][j], outs[i][j],
                           "{}: {name} differs at ctx {i}", isa.token());
            }
        }
    }
}

#[test]
fn simd_arms_hold_the_envelope_against_the_scalar_arm() {
    // cross-arm contract: each non-scalar arm stays within 1e-4 of the
    // scalar arm on the same inputs (FMA contraction is the only
    // difference; it moves last ulps, not the answer)
    let mut ws = Workspace::new();
    let mut rng = Rng::new(22);
    let q = Tensor2::randn(&mut rng, 130, 24, 1.0);
    let k = Tensor2::randn(&mut rng, 130, 24, 1.0);
    let v = Tensor2::randn(&mut rng, 130, 24, 1.0);
    let scalar_ctx = KernelCtx::global().with_isa(Isa::Scalar);
    let base_gemm = gemm_f32(&scalar_ctx, &q, &k_t(&k), &mut ws);
    let base_flash = flash_attention(&scalar_ctx, &q, &k, &v, 0.2, &mut ws);
    for isa in Isa::available() {
        if isa == Isa::Scalar {
            continue;
        }
        let ctx = KernelCtx::global().with_isa(isa);
        let g = gemm_f32(&ctx, &q, &k_t(&k), &mut ws);
        let f = flash_attention(&ctx, &q, &k, &v, 0.2, &mut ws);
        let eg = max_rel_err(&g, &base_gemm);
        let ef = max_rel_err(&f, &base_flash);
        assert!(eg < 1e-4, "{} gemm vs scalar arm: {eg}", isa.token());
        assert!(ef < 1e-4, "{} flash vs scalar arm: {ef}", isa.token());
        ws.put(g.data);
        ws.put(f.data);
    }
}

#[test]
fn batched_attention_matches_per_head_serial() {
    let mut rng = Rng::new(9);
    let reqs: Vec<(Tensor2, Tensor2, Tensor2)> = (0..3)
        .map(|_| {
            (
                Tensor2::randn(&mut rng, 64, 16, 1.0),
                Tensor2::randn(&mut rng, 64, 16, 1.0),
                Tensor2::randn(&mut rng, 64, 16, 1.0),
            )
        })
        .collect();
    let cfg = SpectralShiftConfig::new(8);
    let mut par = BatchedAttention::new(KernelCtx::global());
    let mut ser = BatchedAttention::new(KernelCtx::sequential());
    let a = attention_batched(&mut par, &reqs, 4, &BatchedVariant::SpectralShift(cfg));
    let b = attention_batched(&mut ser, &reqs, 4, &BatchedVariant::SpectralShift(cfg));
    assert_eq!(a.len(), reqs.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.data, y.data, "parallel batch must equal serial batch bitwise");
    }
}
