//! Encoder-stack acceptance tests (the multi-layer-refactor contract):
//!
//! 1. `layers = 1` serves **bitwise** the pre-refactor single-pass
//!    model — reproduced here via the preserved `attention_scatter`
//!    path — so existing caches/traces/parity tests stay meaningful.
//! 2. `layers = 4` matches the scalar multi-layer reference
//!    (`model::reference::forward_ref`) within 1e-4 relative error.
//! 3. Served embeddings are bitwise identical across worker pools
//!    (`workers ∈ {1, 4}`).
//! 4. All six attention variants serve through the one
//!    `AttentionOp`/`EncoderStack` seam.

use ssaformer::attention::Tensor2;
use ssaformer::config::{ServingConfig, Variant};
use ssaformer::coordinator::{
    assemble, attention_scatter, Coordinator, CpuEngine, CpuModel,
    CpuModelConfig, ExecBackend,
};
use ssaformer::kernels::{BatchedAttention, KernelCtx};
use ssaformer::model::reference::forward_ref;
use std::sync::Arc;

fn toks(n: usize, seed: i32) -> Vec<i32> {
    (0..n).map(|i| 3 + ((i as i32 * 31 + seed) % 2000)).collect()
}

/// Same arithmetic as `cpu_engine`'s pooling, reciprocal-multiply
/// included — the bitwise assertions below compare against it, and
/// `x * (1/len)` and `x / len` round differently for non-power-of-two
/// lengths.
fn mean_pool(t: &Tensor2, len: usize) -> Vec<f32> {
    let len = len.min(t.rows).max(1);
    let mut out = vec![0.0f32; t.cols];
    for i in 0..len {
        for (o, v) in out.iter_mut().zip(t.row(i)) {
            *o += *v;
        }
    }
    let inv = 1.0 / len as f32;
    out.iter_mut().for_each(|v| *v *= inv);
    out
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The pre-refactor single-pass pipeline, reproduced exactly: stage a
/// dense (fill × seq × d) buffer, embed each request's aligned rows,
/// fan heads × requests through `attention_scatter`, mean-pool real
/// rows. This is byte-for-byte what `CpuEngine::encode_batch` did
/// before the encoder stack existed.
fn pre_refactor_encode(model: &CpuModel, reqs: &[Vec<i32>], capacity: usize,
                       seq: usize) -> Vec<Vec<f32>> {
    let refs: Vec<&[i32]> = reqs.iter().map(|t| t.as_slice()).collect();
    let lens: Vec<usize> = reqs.iter().map(|t| t.len()).collect();
    let plan = assemble(&refs, capacity, seq);
    let d = model.d_model();
    let per_req = seq * d;
    let mut x = vec![0.0f32; plan.fill * per_req];
    let mut plens = Vec::with_capacity(plan.fill);
    for (r, &len) in lens.iter().enumerate() {
        let plen = model.padded_len(len).min(seq);
        let toks = &plan.tokens[r * seq..r * seq + plen];
        model.embed_into(toks, &mut x[r * per_req..r * per_req + plen * d]);
        plens.push(plen);
    }
    let mut exec = BatchedAttention::new(KernelCtx::global());
    let outs = attention_scatter(&mut exec, &plan, &x, &x, &x, d, &plens,
                                 model.n_heads(), &model.kernel_variant());
    outs.iter().zip(&lens).map(|(t, &len)| mean_pool(t, len)).collect()
}

#[test]
fn layers1_is_bitwise_equal_to_the_pre_refactor_single_pass() {
    let cfg = CpuModelConfig::default();
    assert_eq!(cfg.layers, 1, "default depth must stay the compat model");
    for variant in [Variant::SpectralShift, Variant::Full] {
        let model = CpuModel::new(cfg, variant);
        let verify = CpuModel::new(cfg, variant);
        let reqs = vec![toks(100, 1), toks(128, 2), toks(40, 3)];
        let lens: Vec<usize> = reqs.iter().map(|t| t.len()).collect();
        let refs: Vec<&[i32]> = reqs.iter().map(|t| t.as_slice()).collect();
        let plan = assemble(&refs, 4, 128);
        let mut engine = CpuEngine::new(model);
        let got = engine.encode_batch(&plan, &lens);
        let want = pre_refactor_encode(&verify, &reqs, 4, 128);
        assert_eq!(got.len(), want.len());
        for (r, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(bits(a), bits(b),
                       "{variant:?} req {r}: layers=1 must be bitwise-equal \
                        to the pre-refactor single-pass output");
        }
    }
}

#[test]
fn four_layer_stack_matches_the_scalar_multilayer_reference() {
    let cfg = CpuModelConfig { layers: 4, ffn_mult: 2, ..Default::default() };
    let model = CpuModel::new(cfg, Variant::SpectralShift);
    let verify = CpuModel::new(cfg, Variant::SpectralShift);
    let reqs = vec![toks(100, 4), toks(128, 5), toks(40, 6)];
    let lens: Vec<usize> = reqs.iter().map(|t| t.len()).collect();
    let refs: Vec<&[i32]> = reqs.iter().map(|t| t.as_slice()).collect();
    let plan = assemble(&refs, 4, 128);
    let mut engine = CpuEngine::new(model);
    let got = engine.encode_batch(&plan, &lens);
    for (r, t) in reqs.iter().enumerate() {
        let plen = verify.padded_len(t.len());
        let x = verify.embed_sequence(t, plen);
        let full = forward_ref(verify.stack(), &x);
        let want = mean_pool(&full, t.len());
        for (j, (a, b)) in got[r].iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "req {r} dim {j}: stack {a} vs scalar reference {b}");
        }
    }
}

#[test]
fn served_embeddings_are_bitwise_identical_across_worker_pools() {
    // same 4-layer model, same requests, 1-worker vs 4-worker pools
    // (cache off so every request is computed, not replayed)
    let serve = |workers: usize| -> Vec<Vec<f32>> {
        let cfg = ServingConfig {
            variant: Variant::SpectralShift,
            layers: 4,
            ffn_mult: 2,
            max_batch: 4,
            max_wait_ms: 5,
            queue_capacity: 64,
            workers,
            cache_capacity: 0,
            ..Default::default()
        };
        let engine = Box::new(CpuEngine::new(CpuModel::new(
            CpuModelConfig { layers: cfg.layers, ffn_mult: cfg.ffn_mult,
                             ..Default::default() },
            cfg.variant)));
        let c = Arc::new(Coordinator::start(ExecBackend::Cpu(engine), &cfg)
            .unwrap());
        // concurrent submits so the 4-worker pool actually fans out
        let mut joins = Vec::new();
        for i in 0..6usize {
            let c = c.clone();
            joins.push(std::thread::spawn(move || {
                let t = toks(60 + 20 * i, i as i32);
                c.submit_blocking(t).unwrap().embedding.unwrap()
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    };
    let one = serve(1);
    let four = serve(4);
    for (i, (a, b)) in one.iter().zip(&four).enumerate() {
        assert_eq!(bits(a), bits(b),
                   "req {i}: worker-pool size leaked into the embedding");
    }
}

#[test]
fn all_six_variants_serve_through_the_stack() {
    for variant in [Variant::Full, Variant::Nystrom, Variant::SpectralShift,
                    Variant::Linformer, Variant::Lsh, Variant::Sparse] {
        let cfg = CpuModelConfig { layers: 2, ffn_mult: 2, ..Default::default() };
        let mut a = CpuEngine::new(CpuModel::new(cfg, variant));
        let mut b = CpuEngine::new(CpuModel::new(cfg, variant));
        let t = toks(96, 7);
        let plan = assemble(&[t.as_slice()], 2, 128);
        let ea = a.encode_batch(&plan, &[t.len()]);
        let eb = b.encode_batch(&plan, &[t.len()]);
        assert_eq!(ea[0].len(), a.model().d_model(), "{variant:?}");
        assert!(ea[0].iter().all(|x| x.is_finite()), "{variant:?}");
        assert_eq!(bits(&ea[0]), bits(&eb[0]),
                   "{variant:?}: two engines over one config must serve \
                    one function");
    }
}

#[test]
fn projected_two_layer_stack_matches_the_scalar_projected_reference() {
    // the tentpole acceptance case: with QKV/output projections on, a
    // 2-layer stack must match the scalar projected reference within
    // 1e-4 for ALL SIX variants — the projections wrap around the
    // AttentionOp seam, so every operator gets them for free
    for variant in [Variant::Full, Variant::Nystrom, Variant::SpectralShift,
                    Variant::Linformer, Variant::Lsh, Variant::Sparse] {
        let cfg = CpuModelConfig { layers: 2, ffn_mult: 2, projections: true,
                                   ..Default::default() };
        let model = CpuModel::new(cfg, variant);
        let verify = CpuModel::new(cfg, variant);
        let reqs = vec![toks(100, 9), toks(48, 10)];
        let lens: Vec<usize> = reqs.iter().map(|t| t.len()).collect();
        let refs: Vec<&[i32]> = reqs.iter().map(|t| t.as_slice()).collect();
        let plan = assemble(&refs, 4, 128);
        let mut engine = CpuEngine::new(model);
        if variant == Variant::Lsh {
            // the PR-5 risk note, realized by the SIMD dispatch: LSH
            // bucket assignment is a discontinuous function of the
            // projected values, so the FMA arms' last-ulp differences
            // from the scalar reference can flip a bucket and blow the
            // 1e-4 envelope. Pin the engine to the scalar arm — the
            // projected-LSH parity claim is about the projection seam,
            // not about cross-arm rounding (covered at the kernel level
            // in tests/kernel_parity.rs).
            engine.set_kernel_isa(ssaformer::kernels::Isa::Scalar);
        }
        let got = engine.encode_batch(&plan, &lens);
        for (r, t) in reqs.iter().enumerate() {
            let plen = verify.padded_len(t.len());
            let x = verify.embed_sequence(t, plen);
            let full = forward_ref(verify.stack(), &x);
            let want = mean_pool(&full, t.len());
            for (j, (a, b)) in got[r].iter().zip(&want).enumerate() {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                        "{variant:?} req {r} dim {j}: projected stack {a} \
                         vs scalar reference {b}");
            }
        }
    }
}

#[test]
fn projections_off_keeps_the_pr4_function_and_on_changes_it() {
    // off = the exact PR-4 stack (same seeded draw, bitwise); on is a
    // different served function at depth ≥ 2 and a no-op at depth 1
    let t = toks(64, 11);
    let plan = assemble(&[t.as_slice()], 2, 64);
    let emb = |layers: usize, projections: bool| -> Vec<f32> {
        let cfg = CpuModelConfig { layers, ffn_mult: 2, projections,
                                   ..Default::default() };
        let mut e = CpuEngine::new(CpuModel::new(cfg, Variant::SpectralShift));
        e.encode_batch(&plan, &[t.len()]).remove(0)
    };
    assert_eq!(bits(&emb(1, false)), bits(&emb(1, true)),
               "depth 1 has no projected block — flag must be inert");
    assert_ne!(bits(&emb(2, false)), bits(&emb(2, true)),
               "projections must be load-bearing at depth 2");
}

#[test]
fn per_layer_variant_mixing_serves_and_matches_the_reference() {
    // variant = ss,full — cheap operator below, exact softmax on top
    let cfg = CpuModelConfig { layers: 2, ffn_mult: 2, ..Default::default() };
    let mixed = [Variant::SpectralShift, Variant::Full];
    let model = CpuModel::new_mixed(cfg, &mixed);
    let verify = CpuModel::new_mixed(cfg, &mixed);
    assert_eq!(model.variants(), &mixed);
    let t = toks(96, 12);
    let plan = assemble(&[t.as_slice()], 2, 128);
    let mut engine = CpuEngine::new(model);
    let got = engine.encode_batch(&plan, &[t.len()]);
    let plen = verify.padded_len(t.len());
    let x = verify.embed_sequence(&t, plen);
    let want = mean_pool(&forward_ref(verify.stack(), &x), t.len());
    for (j, (a, b)) in got[0].iter().zip(&want).enumerate() {
        assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0),
                "dim {j}: mixed stack {a} vs scalar reference {b}");
    }
    // mixing is load-bearing: differs from the uniform ss stack
    let mut uniform = CpuEngine::new(CpuModel::new(cfg, Variant::SpectralShift));
    let u = uniform.encode_batch(&plan, &[t.len()]);
    assert_ne!(bits(&got[0]), bits(&u[0]));
}

#[test]
fn deeper_stacks_change_the_served_function() {
    // sanity guard: the extra blocks must actually be load-bearing
    let t = toks(64, 8);
    let plan = assemble(&[t.as_slice()], 2, 64);
    let emb = |layers: usize| -> Vec<f32> {
        let cfg = CpuModelConfig { layers, ffn_mult: 2, ..Default::default() };
        let mut e = CpuEngine::new(CpuModel::new(cfg, Variant::SpectralShift));
        e.encode_batch(&plan, &[t.len()]).remove(0)
    };
    let l1 = emb(1);
    let l2 = emb(2);
    let l4 = emb(4);
    assert_ne!(bits(&l1), bits(&l2));
    assert_ne!(bits(&l2), bits(&l4));
    assert!(l4.iter().all(|x| x.is_finite()));
}
