//! Offline stub of the `xla` PJRT bindings.
//!
//! The real bindings wrap `xla_extension`'s C++ PJRT CPU client. That
//! toolchain is not present in every build environment, so this stub
//! vendors the exact API surface `ssaformer::runtime` uses and makes
//! every runtime entry point return an "unavailable" error instead of
//! linking native code. Artifact-driven paths (serving integration
//! tests, `artifact_exec` / `serving_throughput` benches) already skip
//! gracefully when `artifacts/` is missing, which is always the case
//! when this stub is in use; everything else — the CPU kernel core,
//! attention variants, coordinator logic, analysis benches — is pure
//! Rust and unaffected.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the real crate).

use std::fmt;

/// Error type mirroring the real bindings' `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime not available (offline stub build)"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with device buffers; outer Vec is replicas, inner the
    /// (possibly untupled) outputs.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host-side literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn computation_from_proto_is_constructible() {
        // from_proto is infallible in the real API; the stub keeps that.
        let proto = HloModuleProto { _private: () };
        let _comp = XlaComputation::from_proto(&proto);
    }
}
