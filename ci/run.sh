#!/usr/bin/env bash
# The full verify gate, runnable offline — .github/workflows/ci.yml
# encodes exactly this sequence, so "CI green" and "ci/run.sh passes"
# are the same statement. Run from anywhere; it cd's to the crate.
#
#   ci/run.sh          # build + test (default + scalar arm) + clippy
#                      # + doc + fmt
#   ci/run.sh bench    # additionally regenerate BENCH_kernels.json
#                      # on the reduced smoke shapes (BENCH_SMOKE=1)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# second gate lane: the whole suite again with the kernel dispatch
# forced to the scalar arm, so the portable fallback can never silently
# rot behind a host that always detects avx2/neon
echo "==> SSAF_KERNEL=scalar cargo test -q"
SSAF_KERNEL=scalar cargo test -q

# cluster lane: the multi-replica fault-injection suite, named
# explicitly so a red run reads as "the cluster tier broke" rather than
# a generic test failure, and run three times back to back because the
# suite's contract is determinism — a flake here is a bug, not noise.
# One of the three repeats runs on the scalar kernel arm so the
# cross-replica bitwise-equality pins hold on the portable fallback too.
echo "==> cluster lane: cargo test -q --test integration_cluster (x2 + scalar)"
cargo test -q --test integration_cluster
cargo test -q --test integration_cluster
SSAF_KERNEL=scalar cargo test -q --test integration_cluster

# long-document lane: chunked ENCODE + prefix-reuse cache, named for
# the same reason as the cluster lane. The suite pins hit ≡ recompute
# *bitwise*, so it re-runs on the scalar arm too — the portable
# fallback must preserve the chunk-exactness invariant.
echo "==> longdoc lane: cargo test -q --test integration_longdoc (+ scalar)"
cargo test -q --test integration_longdoc
SSAF_KERNEL=scalar cargo test -q --test integration_longdoc

# admission lane: accuracy-aware admission + quantized tiers. The
# quant kernel unit tests, the wire-level routing suite (on both kernel
# arms — the full-f32 bitwise pin must hold on the portable fallback
# too), then the env-override check once per tier: SSAF_ADMISSION
# outranks the [serving] admission knob, so only the override-aware
# test runs under the forced env (the rest of the suite asserts
# auto-policy replies and would be meaningless there).
echo "==> admission lane: cargo test -q --test integration_admission (+ scalar + forced tiers)"
cargo test -q --lib quant
cargo test -q --test integration_admission
SSAF_KERNEL=scalar cargo test -q --test integration_admission
for tier in full-f32 ss-f32 ss-bf16 ss-int8; do
    SSAF_ADMISSION="$tier" cargo test -q --test integration_admission \
        env_override
done

# train lane: the deterministic CPU trainer end to end — train a
# projected 3-layer encoder (smoke schedule), checkpoint it, serve the
# checkpoint over TCP through init=load, and sweep every variant's
# error bound on the trained weights (writes BENCH_error_bound.json at
# the repo root). The example exits non-zero if the loss curve is not
# strictly decreasing or the served reply diverges from the in-process
# forward.
echo "==> train lane: cargo run --release --example train_tiny -- --smoke"
cargo run --release --example train_tiny -- --smoke

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "==> cargo fmt --check"
cargo fmt --check

if [[ "${1:-}" == "bench" ]]; then
    echo "==> BENCH_SMOKE=1 cargo bench --bench bench_snapshot"
    BENCH_SMOKE=1 cargo bench --bench bench_snapshot
fi

echo "ci/run.sh: all gates green"
