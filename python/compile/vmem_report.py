"""L1 perf analysis: VMEM footprint + MXU utilization estimates.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so the
Pallas kernels are optimized structurally: this tool computes, per kernel
and blocking configuration, the peak VMEM residency and an MXU
utilization estimate (fraction of the 128×128 systolic array covered by
each contraction, times the f32-vs-bf16 issue-rate factor), which is what
DESIGN.md §Perf targets.

Usage: cd python && python -m compile.vmem_report [--n N] [--c C] [--d D]
                      [--block-q B] [--block-k B]
"""

from __future__ import annotations

import argparse

VMEM_BYTES = 16 << 20  # 16 MiB per TensorCore
MXU = 128              # systolic array dimension

F32 = 4


def fmt_bytes(b: float) -> str:
    if b < 1 << 10:
        return f"{b:.0f}B"
    if b < 1 << 20:
        return f"{b / (1 << 10):.1f}KiB"
    return f"{b / (1 << 20):.2f}MiB"


def mxu_util(m: int, k: int, n: int, dtype_factor: float = 0.5) -> float:
    """Utilization estimate for an (m×k)·(k×n) contraction on a 128×128
    MXU: lane coverage of the k (contraction) and n (output) dims, times
    the dtype issue-rate factor (f32 = 0.5 of bf16 peak)."""
    cover_k = min(k, MXU) / MXU
    cover_n = min(n, MXU) / MXU
    # m only affects pipeline fill, amortized for m >= 128
    fill = min(m, MXU) / MXU if m < MXU else 1.0
    return cover_k * cover_n * fill * dtype_factor


def kernel_report(n: int, c: int, d: int, dv: int, block_q: int,
                  block_k: int) -> list[tuple[str, int, str]]:
    """[(kernel, peak VMEM bytes, MXU note)] for the SS attention path."""
    bq = min(block_q, n)
    bk = min(block_k, n)
    rows = []

    # segment-means pair: both (n,d) inputs + (c,d)x2 outputs resident
    seg = 2 * n * d * F32 + 2 * c * d * F32
    rows.append(("segment_means_pair", seg, "reduction only (VPU, no MXU)"))

    # flash exact attention (the full-variant baseline)
    flash = bq * d * F32 + 2 * bk * d * F32 + bq * bk * F32 + bq * dv * F32
    rows.append((f"flash attention (bq={bq},bk={bk})", flash,
                 f"QKᵀ util {mxu_util(bq, d, bk):.2f}, PV util {mxu_util(bq, bk, dv):.2f}"))

    # landmark cross attention: qt resident + k/v chunks + scores + acc
    cross = c * d * F32 + 2 * bk * d * F32 + c * bk * F32 + c * dv * F32
    rows.append((f"landmark cross-attn (bk={bk})", cross,
                 f"Q̃Kᵀ util {mxu_util(c, d, bk):.2f}, PV util {mxu_util(c, bk, dv):.2f}"))

    # NS pinv: 4 c×c buffers
    ns = 4 * c * c * F32
    rows.append((f"ns_pinv ord-7 (c={c})", ns,
                 f"c×c matmul util {mxu_util(c, c, c):.2f} (pad c→128 to raise)"))

    # combine: q block + kt + mw + v block + out
    comb = bq * d * F32 + c * d * F32 + c * dv * F32 + 2 * bq * dv * F32
    rows.append((f"ss combine (bq={bq})", comb,
                 f"QK̃ᵀ util {mxu_util(bq, d, c):.2f}, F·MW util {mxu_util(bq, c, dv):.2f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--c", type=int, default=64)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--dv", type=int, default=None)
    ap.add_argument("--block-q", type=int, default=512)
    ap.add_argument("--block-k", type=int, default=512)
    args = ap.parse_args()
    dv = args.dv or args.d

    print(f"L1 structural perf report — n={args.n} c={args.c} d={args.d} "
          f"dv={dv} block_q={args.block_q} block_k={args.block_k}")
    print(f"VMEM budget {fmt_bytes(VMEM_BYTES)}; MXU {MXU}x{MXU}; "
          f"f32 issue factor 0.5\n")
    rows = kernel_report(args.n, args.c, args.d, dv, args.block_q, args.block_k)
    width = max(len(r[0]) for r in rows)
    ok_all = True
    for name, vmem, note in rows:
        ok = vmem <= VMEM_BYTES
        ok_all &= ok
        print(f"  {name:<{width}}  {fmt_bytes(vmem):>10}  "
              f"{'OK ' if ok else 'OVER'}  {note}")
    print(f"\nall kernels within VMEM: {'yes' if ok_all else 'NO'}")
    # headline ratios
    print("\nheadline: the dominant contractions run at "
          f"{mxu_util(min(args.block_q, args.n), args.d, args.c):.0%} "
          "(F factor) and "
          f"{mxu_util(args.c, args.d, min(args.block_k, args.n)):.0%} "
          "(B factor) of f32 MXU peak; padding c,d to 128 (bf16) would "
          "reach ~50-100% — recorded in EXPERIMENTS.md §Perf L1.")


if __name__ == "__main__":
    main()
