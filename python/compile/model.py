"""Layer-2: JAX transformer encoder with pluggable attention.

A small BERT-style masked-LM encoder whose attention is one of
  * "full"    — exact softmax attention (Pallas flash kernel)
  * "nystrom" — Nystromformer (paper sec 2.4)
  * "ss"      — modified spectral shifting (paper sec 5, the contribution)

Parameters, Adam state, and activations are all plain f32; the parameter
pytree is flattened into a SINGLE f32 vector with a static layout
(`ParamLayout`) so the rust runtime exchanges exactly one params literal
with the AOT artifacts — no pytree marshalling across the FFI.

Exported artifact entry points (see aot.py):
  encode_fn      (params, tokens)                    -> pooled embeddings
  logits_fn      (params, tokens)                    -> MLM logits
  train_step_fn  (params, m, v, step, tokens,
                  targets, loss_mask)                -> params', m', v', loss

Everything lowered into artifacts is matmul/softmax-only (no LAPACK
custom-calls) so the old xla_extension 0.5.1 CPU runtime can execute it.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.autodiff import (
    nystrom_attention_ad,
    softmax_attention_ad,
    spectral_shift_attention_ad,
)

__all__ = ["ModelConfig", "ParamLayout", "init_params", "forward",
           "encode_fn", "logits_fn", "loss_fn", "train_step_fn",
           "count_params"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static transformer hyperparameters (baked into each artifact)."""

    vocab: int = 2048
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 1024
    seq_len: int = 128
    # pos-embedding capacity: all artifacts share one flat param vector,
    # so the pos table is sized by max_seq (not seq_len) and forward
    # slices the first seq_len rows
    max_seq: int = 1024
    attention: str = "ss"          # "full" | "nystrom" | "ss"
    landmarks: int = 32            # c; seq_len must be divisible by it
    pinv_iters: int = 8
    middle_form: str = "eq8"
    add_shift_identity: bool = True
    block_q: int = 128
    block_k: int = 128
    # Adam
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def validate(self) -> "ModelConfig":
        if self.attention not in ("full", "nystrom", "ss"):
            raise ValueError(f"unknown attention {self.attention!r}")
        if self.attention != "full" and self.seq_len % self.landmarks:
            raise ValueError(
                f"seq_len={self.seq_len} not divisible by landmarks={self.landmarks}")
        if self.seq_len > self.max_seq:
            raise ValueError(
                f"seq_len={self.seq_len} exceeds max_seq={self.max_seq}")
        return self


class ParamLayout:
    """Static name -> (offset, shape) layout of the flat parameter vector.

    Layout order is deterministic (insertion order below) and recorded in
    the artifact manifest so the rust side can introspect params by name.
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.entries: list[tuple[str, tuple[int, ...]]] = []
        d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab
        self._add("embed", (v, d))
        # sized by max_seq so every seq-bucket artifact shares the layout
        self._add("pos", (cfg.max_seq, d))
        for i in range(cfg.n_layers):
            p = f"layer{i}."
            self._add(p + "ln1_g", (d,))
            self._add(p + "ln1_b", (d,))
            self._add(p + "wq", (d, d))
            self._add(p + "wk", (d, d))
            self._add(p + "wv", (d, d))
            self._add(p + "wo", (d, d))
            self._add(p + "ln2_g", (d,))
            self._add(p + "ln2_b", (d,))
            self._add(p + "w_ff1", (d, dff))
            self._add(p + "b_ff1", (dff,))
            self._add(p + "w_ff2", (dff, d))
            self._add(p + "b_ff2", (d,))
        self._add("ln_f_g", (d,))
        self._add("ln_f_b", (d,))
        self._add("head_b", (v,))  # LM head weight is tied to embed

        self.offsets: dict[str, tuple[int, tuple[int, ...]]] = {}
        off = 0
        for name, shape in self.entries:
            size = int(np.prod(shape))
            self.offsets[name] = (off, shape)
            off += size
        self.total = off

    def _add(self, name: str, shape: tuple[int, ...]):
        self.entries.append((name, shape))

    def slice(self, flat, name: str):
        """Static slice of the flat vector (lowered to a constant-offset
        slice op — free after XLA fusion)."""
        off, shape = self.offsets[name]
        size = int(np.prod(shape))
        return jax.lax.slice(flat, (off,), (off + size,)).reshape(shape)


@functools.lru_cache(maxsize=None)
def _layout(cfg: ModelConfig) -> ParamLayout:
    return ParamLayout(cfg)


def count_params(cfg: ModelConfig) -> int:
    """Total number of scalar parameters for this config."""
    return _layout(cfg).total


def init_params(cfg: ModelConfig, seed: int = 0) -> np.ndarray:
    """Initialize the flat parameter vector (numpy, build-time only).

    Scaled-normal init for matmuls (1/sqrt(fan_in)), 0.02-normal for
    embeddings, ones/zeros for layernorm gains/biases.
    """
    lay = _layout(cfg)
    rng = np.random.default_rng(seed)
    flat = np.zeros(lay.total, np.float32)
    for name, shape in lay.entries:
        off, _ = lay.offsets[name]
        size = int(np.prod(shape))
        view = flat[off:off + size]
        if name.endswith(("_g",)):
            view[:] = 1.0
        elif name.endswith(("_b",)) or name.startswith("head_b"):
            view[:] = 0.0
        elif name in ("embed", "pos"):
            view[:] = rng.normal(0.0, 0.02, size).astype(np.float32)
        else:  # weight matrices
            fan_in = shape[0]
            view[:] = rng.normal(0.0, 1.0 / math.sqrt(fan_in), size).astype(np.float32)
    return flat


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention_one(cfg: ModelConfig, q, k, v):
    """Single (n, d_head) attention dispatch. q,k,v: (n, dh)."""
    if cfg.attention == "full":
        return softmax_attention_ad(q, k, v, block_q=cfg.block_q,
                                    block_k=cfg.block_k)
    if cfg.attention == "nystrom":
        return nystrom_attention_ad(q, k, v, cfg.landmarks,
                                    pinv_iters=cfg.pinv_iters,
                                    block_q=cfg.block_q, block_k=cfg.block_k)
    return spectral_shift_attention_ad(
        q, k, v, cfg.landmarks, pinv_iters=cfg.pinv_iters,
        middle_form=cfg.middle_form,
        add_shift_identity=cfg.add_shift_identity,
        block_q=cfg.block_q, block_k=cfg.block_k)


def _mha(cfg: ModelConfig, lay: ParamLayout, flat, prefix, x):
    """Multi-head attention over x: (B, n, d). Heads and batch are folded
    into one leading vmap axis so the Pallas kernel sees (n, dh) blocks."""
    b, n, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    wq = lay.slice(flat, prefix + "wq")
    wk = lay.slice(flat, prefix + "wk")
    wv = lay.slice(flat, prefix + "wv")
    wo = lay.slice(flat, prefix + "wo")
    q = (x @ wq).reshape(b, n, h, dh).transpose(0, 2, 1, 3).reshape(b * h, n, dh)
    k = (x @ wk).reshape(b, n, h, dh).transpose(0, 2, 1, 3).reshape(b * h, n, dh)
    v = (x @ wv).reshape(b, n, h, dh).transpose(0, 2, 1, 3).reshape(b * h, n, dh)
    o = jax.vmap(lambda qi, ki, vi: _attention_one(cfg, qi, ki, vi))(q, k, v)
    o = o.reshape(b, h, n, dh).transpose(0, 2, 1, 3).reshape(b, n, d)
    return o @ wo


def forward(cfg: ModelConfig, flat, tokens):
    """Encoder forward: tokens (B, n) int32 -> hidden states (B, n, d)."""
    lay = _layout(cfg)
    embed = lay.slice(flat, "embed")
    n = tokens.shape[1]
    pos = lay.slice(flat, "pos")[:n]
    x = embed[tokens] + pos[None, :, :]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = _layer_norm(x, lay.slice(flat, p + "ln1_g"), lay.slice(flat, p + "ln1_b"))
        x = x + _mha(cfg, lay, flat, p, h)
        h = _layer_norm(x, lay.slice(flat, p + "ln2_g"), lay.slice(flat, p + "ln2_b"))
        h = jax.nn.gelu(h @ lay.slice(flat, p + "w_ff1") + lay.slice(flat, p + "b_ff1"))
        x = x + h @ lay.slice(flat, p + "w_ff2") + lay.slice(flat, p + "b_ff2")
    return _layer_norm(x, lay.slice(flat, "ln_f_g"), lay.slice(flat, "ln_f_b"))


def encode_fn(cfg: ModelConfig, flat, tokens):
    """Serving entry point: mean-pooled sequence embedding (B, d)."""
    h = forward(cfg, flat, tokens)
    return jnp.mean(h, axis=1)


def logits_fn(cfg: ModelConfig, flat, tokens):
    """MLM logits (B, n, vocab) with the LM head tied to the embedding."""
    lay = _layout(cfg)
    h = forward(cfg, flat, tokens)
    embed = lay.slice(flat, "embed")
    return h @ embed.T + lay.slice(flat, "head_b")


def loss_fn(cfg: ModelConfig, flat, tokens, targets, loss_mask):
    """Masked cross-entropy: mean over positions where loss_mask==1."""
    logits = logits_fn(cfg, flat, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(loss_mask), 1.0)
    return jnp.sum(nll * loss_mask) / denom


def train_step_fn(cfg: ModelConfig, flat, m, v, step, tokens, targets, loss_mask):
    """One Adam step. All state is flat f32 vectors; ``step`` is a f32
    scalar (1-based) used for bias correction. Returns
    (params', m', v', loss)."""
    loss, grad = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, targets, loss_mask))(flat)
    m2 = cfg.beta1 * m + (1.0 - cfg.beta1) * grad
    v2 = cfg.beta2 * v + (1.0 - cfg.beta2) * grad * grad
    mhat = m2 / (1.0 - cfg.beta1 ** step)
    vhat = v2 / (1.0 - cfg.beta2 ** step)
    flat2 = flat - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
    return flat2, m2, v2, loss
