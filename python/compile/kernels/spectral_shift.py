"""Pallas kernels: modified spectral-shifting attention (paper sec 5).

The full approximation, eq (8) plus the δIₙ add-back from the SS model:

    out = F · [A⁺ (I_c − δ A⁺)] · (B V)  +  δ V
    F = L(Q K̃ᵀ/√d)   A = L(Q̃ K̃ᵀ/√d)   B = L(Q̃ Kᵀ/√d)

decomposed into four pieces, each sized for VMEM residency:

  1. segment_means_pallas   — landmarks Q̃, K̃ (kernels/landmarks.py)
  2. A_s + Newton-Schulz Z* + δ̂  — c×c work, ns_pinv_pallas
     (kernels/pinv_iter.py) + matmul-only δ estimator (ref.delta_ss_iterative)
  3. landmark_cross_attention_pallas — W = B·V streamed over keys
     (kernels/cross_attn.py)
  4. _combine kernel (here)  — per query block: F_blk · (M W) + δ V_blk,
     where M = Z*(I − δZ*) is precomputed once (c×c).

Nystromformer (paper sec 2.4) is the δ=0 / M=Z* special case and is
exposed from the same machinery (`nystrom_attention_pallas`).

Everything on the artifact path is matmul/softmax-only — no LAPACK
custom-calls — so the lowered HLO runs on the rust PJRT CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .cross_attn import landmark_cross_attention_pallas
from .landmarks import segment_means_pair_pallas, segment_means_pallas
from .pinv_iter import ns_pinv_pallas

__all__ = [
    "spectral_shift_attention_pallas",
    "nystrom_attention_pallas",
    "ss_middle_factor",
]


def _combine_kernel(q_ref, kt_ref, mw_ref, v_ref, delta_ref, o_ref, *, scale):
    """o_blk = rowsoftmax(q_blk k̃ᵀ·scale) @ MW + δ·v_blk.

    The F-factor softmax normalizes over only c landmark columns, so each
    query block is self-contained (no cross-block recurrence needed).
    """
    q = q_ref[...].astype(jnp.float32)      # (bq, d)
    kt = kt_ref[...].astype(jnp.float32)    # (c, d)
    mw = mw_ref[...].astype(jnp.float32)    # (c, dv)
    v = v_ref[...].astype(jnp.float32)      # (bq, dv)
    delta = delta_ref[0, 0].astype(jnp.float32)
    s = (q @ kt.T) * scale                  # (bq, c)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    f = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = (f @ mw + delta * v).astype(o_ref.dtype)


def _combine(q, kt, mw, v, delta, scale, block_q):
    n, d = q.shape
    c = kt.shape[0]
    dv = v.shape[1]
    block_q = min(block_q, n)
    if n % block_q:
        raise ValueError(f"n={n} not divisible by block_q={block_q}")
    delta_arr = jnp.reshape(delta.astype(q.dtype), (1, 1))
    kernel = functools.partial(_combine_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((c, d), lambda i: (0, 0)),
            pl.BlockSpec((c, dv), lambda i: (0, 0)),
            pl.BlockSpec((block_q, dv), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), q.dtype),
        interpret=True,
    )(q, kt, mw, v, delta_arr)


def ss_middle_factor(a, z, delta, middle_form="eq8"):
    """M = A⁺(I − δA⁺) (eq 8, derivation-consistent) or A⁺(I − δA) (eq 4,
    as printed). ``z`` is the iterative pseudoinverse standing in for A⁺."""
    eye = jnp.eye(a.shape[0], dtype=a.dtype)
    if middle_form == "eq8":
        return z @ (eye - delta * z)
    if middle_form == "eq4":
        return z @ (eye - delta * a)
    raise ValueError(f"middle_form must be 'eq8' or 'eq4', got {middle_form!r}")


def spectral_shift_attention_pallas(
    q, k, v, c,
    scale=None,
    pinv_iters=8,
    middle_form="eq8",
    add_shift_identity=True,
    block_q=128,
    block_k=128,
):
    """Modified spectral-shifting attention, O(n) in sequence length.

    q, k: (n, d); v: (n, dv); c landmarks (n divisible by c). Returns
    (n, dv). All Pallas pieces run interpret=True (CPU correctness path);
    see DESIGN.md §Hardware-Adaptation for the real-TPU mapping.
    """
    n, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qt, kt = segment_means_pair_pallas(q, k, c)
    # A_s = L(Q̃K̃ᵀ·scale): c×c, a single fused XLA op — too small to
    # benefit from a dedicated kernel.
    a = jax.nn.softmax((qt.astype(jnp.float32) @ kt.astype(jnp.float32).T)
                       * scale, axis=-1)
    z = ns_pinv_pallas(a, iters=pinv_iters, order=7)
    delta = ref.delta_ss_iterative(a, z=z)
    m = ss_middle_factor(a, z, delta, middle_form)
    w = landmark_cross_attention_pallas(qt, k, v, scale=scale, block_k=block_k)
    mw = (m @ w.astype(jnp.float32)).astype(q.dtype)
    if not add_shift_identity:
        delta_out = jnp.zeros((), q.dtype)
    else:
        delta_out = delta
    return _combine(q, kt, mw, v, delta_out, scale, block_q)


def nystrom_attention_pallas(q, k, v, c, scale=None, pinv_iters=8,
                             block_q=128, block_k=128):
    """Nystromformer attention (paper sec 2.4): the δ=0 special case."""
    n, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qt, kt = segment_means_pair_pallas(q, k, c)
    a = jax.nn.softmax((qt.astype(jnp.float32) @ kt.astype(jnp.float32).T)
                       * scale, axis=-1)
    z = ns_pinv_pallas(a, iters=pinv_iters, order=7)
    w = landmark_cross_attention_pallas(qt, k, v, scale=scale, block_k=block_k)
    mw = (z @ w.astype(jnp.float32)).astype(q.dtype)
    zero = jnp.zeros((), q.dtype)
    return _combine(q, kt, mw, v, zero, scale, block_q)
