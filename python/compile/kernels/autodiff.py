"""Differentiable wrappers around the Pallas kernels.

``pallas_call`` has no reverse-mode rule (even in interpret mode), so each
attention variant is wrapped in ``jax.custom_vjp``: the forward pass runs
the Pallas kernel, the backward pass is the VJP of the pure-jnp reference
implementation of the *same* iterative algorithm (ref.*_ns — matches the
kernel to ~1e-7, see python/tests/test_spectral_shift.py), re-running the
forward inside the VJP. This costs one extra forward in the backward pass
(standard rematerialization trade: no n×c residuals are stored).

These wrappers are what the L2 model (model.py) calls, so the same code
path serves both the AOT forward artifacts and the train-step artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .softmax_attn import softmax_attention_pallas
from .spectral_shift import (
    nystrom_attention_pallas,
    spectral_shift_attention_pallas,
)

__all__ = [
    "softmax_attention_ad",
    "nystrom_attention_ad",
    "spectral_shift_attention_ad",
]


def _make_ad(pallas_fn, ref_fn):
    """custom_vjp: pallas forward, ref-function VJP backward."""

    @jax.custom_vjp
    def attn(q, k, v):
        return pallas_fn(q, k, v)

    def fwd(q, k, v):
        return pallas_fn(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(ref_fn, q, k, v)
        return vjp(g)

    attn.defvjp(fwd, bwd)
    return attn


@functools.lru_cache(maxsize=None)
def _softmax_ad_cached(block_q, block_k):
    return _make_ad(
        lambda q, k, v: softmax_attention_pallas(q, k, v, block_q=block_q,
                                                 block_k=block_k),
        lambda q, k, v: ref.softmax_attention(q, k, v),
    )


@functools.lru_cache(maxsize=None)
def _nystrom_ad_cached(c, pinv_iters, block_q, block_k):
    return _make_ad(
        lambda q, k, v: nystrom_attention_pallas(
            q, k, v, c, pinv_iters=pinv_iters, block_q=block_q, block_k=block_k),
        lambda q, k, v: ref.nystrom_attention_ns(q, k, v, c,
                                                 pinv_iters=pinv_iters),
    )


@functools.lru_cache(maxsize=None)
def _ss_ad_cached(c, pinv_iters, middle_form, add_shift_identity,
                  block_q, block_k):
    return _make_ad(
        lambda q, k, v: spectral_shift_attention_pallas(
            q, k, v, c, pinv_iters=pinv_iters, middle_form=middle_form,
            add_shift_identity=add_shift_identity,
            block_q=block_q, block_k=block_k),
        lambda q, k, v: ref.spectral_shift_attention_ns(
            q, k, v, c, pinv_iters=pinv_iters, middle_form=middle_form,
            add_shift_identity=add_shift_identity),
    )


def softmax_attention_ad(q, k, v, block_q=128, block_k=128):
    """Differentiable exact attention (Pallas fwd, jnp-ref bwd)."""
    return _softmax_ad_cached(block_q, block_k)(q, k, v)


def nystrom_attention_ad(q, k, v, c, pinv_iters=8, block_q=128, block_k=128):
    """Differentiable Nystromformer attention."""
    return _nystrom_ad_cached(c, pinv_iters, block_q, block_k)(q, k, v)


def spectral_shift_attention_ad(q, k, v, c, pinv_iters=8, middle_form="eq8",
                                add_shift_identity=True,
                                block_q=128, block_k=128):
    """Differentiable spectral-shifting attention (the paper's method)."""
    return _ss_ad_cached(c, pinv_iters, middle_form, add_shift_identity,
                         block_q, block_k)(q, k, v)
