"""Pallas kernel: landmark cross-attention W = L(Q̃ Kᵀ · scale) · V.

Shared by Nystromformer and spectral shifting: this is the B-factor
(paper sec 2.4 / sec 5) contracted with V without ever materializing the
c×n matrix B. The row-wise softmax of B runs over the *full* n key axis,
so the kernel uses the online-softmax recurrence over block_k chunks —
this is exactly the constraint Figure 1 of the paper illustrates (row
softmax needs every column), solved by streaming.

TPU mapping: Q̃ (c×d, ≤ 32 KiB) stays VMEM-resident for the whole grid;
K/V stream through in block_k chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["landmark_cross_attention_pallas"]


def _cross_kernel(qt_ref, k_ref, v_ref, w_ref, *, scale, block_k):
    qt = qt_ref[...].astype(jnp.float32)  # (c, d)
    k = k_ref[...].astype(jnp.float32)    # (n, d)
    v = v_ref[...].astype(jnp.float32)    # (n, dv)
    c = qt.shape[0]
    n = k.shape[0]
    dv = v.shape[1]
    nk = n // block_k

    def body(i, carry):
        m_prev, l_prev, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, 0)
        vc = jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, 0)
        s = (qt @ kc.T) * scale                      # (c, bk)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ vc
        return m_new, l_new, acc

    m0 = jnp.full((c,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((c,), jnp.float32)
    acc0 = jnp.zeros((c, dv), jnp.float32)
    _, l_fin, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    w_ref[...] = (acc / l_fin[:, None]).astype(w_ref.dtype)


def landmark_cross_attention_pallas(qt, k, v, scale=None, block_k=128):
    """W = rowsoftmax(qt kᵀ · scale) v, streamed over the key axis.

    qt: (c, d) landmarks, k: (n, d), v: (n, dv) -> (c, dv).
    """
    c, d = qt.shape
    n, dv = v.shape
    block_k = min(block_k, n)
    if n % block_k:
        raise ValueError(f"n={n} not divisible by block_k={block_k}")
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_cross_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((c, dv), qt.dtype),
        interpret=True,
    )(qt, k, v)
