"""Pure-jnp reference oracle for every kernel in this package.

These are the CORRECTNESS ground truth: straightforward, unblocked
implementations of

  * exact softmax self-attention                       (paper sec 2.1)
  * segment-means landmark selection                   (paper sec 2.3, eq 1)
  * Nystromformer attention                            (paper sec 2.4)
  * modified spectral-shifting attention               (paper sec 5, eq 8/10)
  * the spectral-shift parameters (delta_ss, U_ss)     (paper sec 4)
  * Newton-Schulz iterative pseudoinverse              (paper sec 7, eq 11)

Pallas kernels in this package are tested against these functions with
``numpy.testing.assert_allclose`` (see python/tests/).

NOTE on numerics: functions here may use ``jnp.linalg`` (SVD-backed pinv).
Anything that is lowered into an AOT artifact for the rust runtime must NOT
go through ``jnp.linalg`` (old xla_extension 0.5.1 lacks jax>=0.5's LAPACK
FFI custom-calls); the artifact path uses the Newton-Schulz pinv instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "softmax_attention",
    "segment_means",
    "attention_factors",
    "nystrom_attention",
    "delta_ss_exact",
    "u_ss_exact",
    "spectral_shift_attention",
    "spectral_shift_matrix",
    "ns_pinv_ord3",
    "ns_pinv_ord7",
    "ns_init",
    "delta_ss_iterative",
    "nystrom_attention_ns",
    "spectral_shift_attention_ns",
]


def softmax_attention(q, k, v, scale=None):
    """Exact self-attention ``softmax(q kᵀ · scale) v``.

    q: (n, d), k: (m, d), v: (m, dv) -> (n, dv).
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jax.nn.softmax((q @ k.T) * scale, axis=-1)
    return s @ v


def segment_means(x, c):
    """Segment-means landmark selection (paper eq 1).

    Splits the n rows of ``x`` into ``c`` contiguous segments of length
    l = n // c and returns the per-segment mean: (n, d) -> (c, d).
    n must be divisible by c (pad upstream).
    """
    n, d = x.shape
    if n % c != 0:
        raise ValueError(f"n={n} not divisible by c={c}")
    return x.reshape(c, n // c, d).mean(axis=1)


def attention_factors(q, k, c, scale=None):
    """The three softmax factors shared by Nystromformer and spectral shifting.

    Returns (F, A, B) with
      F = L(q k̃ᵀ·scale)   (n, c)   "kernel_1" in Nystromformer
      A = L(q̃ k̃ᵀ·scale)   (c, c)   the sampled landmark block A_s
      B = L(q̃ kᵀ·scale)   (c, n)   "kernel_3"
    where L is row-wise softmax and q̃, k̃ are segment-means landmarks.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    qt = segment_means(q, c)
    kt = segment_means(k, c)
    f = jax.nn.softmax((q @ kt.T) * scale, axis=-1)
    a = jax.nn.softmax((qt @ kt.T) * scale, axis=-1)
    b = jax.nn.softmax((qt @ k.T) * scale, axis=-1)
    return f, a, b


def nystrom_attention(q, k, v, c, scale=None):
    """Nystromformer attention (paper sec 2.4): F · A⁺ · (B v)."""
    f, a, b = attention_factors(q, k, c, scale)
    return f @ (jnp.linalg.pinv(a) @ (b @ v))


def delta_ss_exact(a, rank_rtol=1e-6):
    """Spectral shift parameter, SVD-based (paper sec 4 closed form).

      delta = (tr(A) - tr(A⁺ A²)) / (c - rank(A))

    ``rank_rtol`` sets the numerical-rank tolerance (relative to the top
    singular value). For numerically full-rank A the numerator and
    denominator both vanish; we return 0 in that case (the model
    degenerates to the prototype / Nystrom model — the correct limit).
    """
    c = a.shape[0]
    s = jnp.linalg.svd(a, compute_uv=False)
    r = jnp.sum(s > rank_rtol * s[0])
    pinv = jnp.linalg.pinv(a, rtol=rank_rtol)
    num = jnp.trace(a) - jnp.trace(pinv @ a @ a)
    den = c - r
    return jnp.where(den > 0, num / jnp.maximum(den, 1), 0.0).astype(a.dtype)


def u_ss_exact(a, rank_rtol=1e-6):
    """U^SS = A⁺ - delta^SS (A²)⁺  (paper sec 4, symmetric-K closed form).

    Returns (U^SS, delta^SS).
    """
    delta = delta_ss_exact(a, rank_rtol)
    pinv = jnp.linalg.pinv(a, rtol=rank_rtol)
    pinv2 = jnp.linalg.pinv(a @ a, rtol=rank_rtol)
    return pinv - delta * pinv2, delta


def _middle(pinv, a, delta, middle_form):
    eye = jnp.eye(a.shape[0], dtype=a.dtype)
    if middle_form == "eq8":
        return pinv @ (eye - delta * pinv)
    if middle_form == "eq4":
        return pinv @ (eye - delta * a)
    raise ValueError(f"middle_form must be 'eq8' or 'eq4', got {middle_form!r}")


def spectral_shift_matrix(q, k, c, scale=None, rank_rtol=1e-6,
                          middle_form="eq8", add_shift_identity=True):
    """Dense n×n spectral-shifting approximation of softmax attention.

    eq8 (derivation, eqs 6-8):  S̃ = F · A⁺ (I_c − δ A⁺) · B  [+ δ Iₙ]
    eq4 (as printed, eq 4/10):  S̃ = F · A⁺ (I_c − δ A)  · B  [+ δ Iₙ]

    Used by spectrum-analysis tests (Figure 2); O(n²) memory, test-only.
    """
    n = q.shape[0]
    f, a, b = attention_factors(q, k, c, scale)
    pinv = jnp.linalg.pinv(a, rtol=rank_rtol)
    delta = delta_ss_exact(a, rank_rtol)
    s = f @ _middle(pinv, a, delta, middle_form) @ b
    if add_shift_identity:
        s = s + delta * jnp.eye(n, dtype=s.dtype)
    return s


def spectral_shift_attention(q, k, v, c, scale=None, rank_rtol=1e-6,
                             middle_form="eq8", add_shift_identity=True):
    """Modified spectral-shifting attention (paper sec 5).

    O(n·c) reference: never forms the n×n matrix;
      out = F · [A⁺ (I − δ A⁺)] · (B v)  + δ v     (eq 8 + the δIₙ add-back)
    """
    f, a, b = attention_factors(q, k, c, scale)
    pinv = jnp.linalg.pinv(a, rtol=rank_rtol)
    delta = delta_ss_exact(a, rank_rtol)
    out = f @ (_middle(pinv, a, delta, middle_form) @ (b @ v))
    if add_shift_identity:
        out = out + delta * v
    return out


# ---------------------------------------------------------------------------
# Iterative pseudoinverse (paper sec 7 eq 11) — artifact-safe (matmul only).
# ---------------------------------------------------------------------------


def ns_init(a):
    """Z₀ = Aᵀ / (‖A‖₁ ‖A‖∞) — satisfies ‖A A⁺ − A Z₀‖ < 1 (Nystromformer)."""
    n1 = jnp.max(jnp.sum(jnp.abs(a), axis=0))   # max column sum = ‖A‖₁
    ninf = jnp.max(jnp.sum(jnp.abs(a), axis=1))  # max row sum = ‖A‖∞
    return a.T / (n1 * ninf)


def ns_pinv_ord3(a, iters=24):
    """Cubic (order-3) Newton-Schulz baseline:

      Z_{j+1} = Z_j (3 I − A Z_j (3 I − A Z_j))

    Kept as the comparison iteration for E6 (pinv_convergence bench).
    """
    eye = jnp.eye(a.shape[0], dtype=a.dtype)

    def body(_, z):
        az = a @ z
        return z @ (3.0 * eye - az @ (3.0 * eye - az))

    return jax.lax.fori_loop(0, iters, body, ns_init(a))


def ns_pinv_ord7(a, iters=8, z0=None):
    """The paper's eq (11) iteration (same as Nystromformer eq 15):

      Z_{j+1} = ¼ Z_j (13 I − A Z_j (15 I − A Z_j (7 I − A Z_j)))

    Seventh-order residual decay; 6-8 iterations suffice for softmax
    landmark blocks.
    """
    eye = jnp.eye(a.shape[0], dtype=a.dtype)
    if z0 is None:
        z0 = ns_init(a)

    def body(_, z):
        az = a @ z
        return 0.25 * z @ (13.0 * eye - az @ (15.0 * eye - az @ (7.0 * eye - az)))

    return jax.lax.fori_loop(0, iters, body, z0)


def nystrom_attention_ns(q, k, v, c, scale=None, pinv_iters=8):
    """Nystromformer attention with the eq-11 iterative pseudoinverse —
    the exact computation the Pallas path implements (apples-to-apples
    oracle for kernel tests; ``nystrom_attention`` is the SVD-pinv ideal).
    """
    f, a, b = attention_factors(q, k, c, scale)
    z = ns_pinv_ord7(a.astype(jnp.float32), iters=pinv_iters)
    return (f @ (z @ (b @ v).astype(jnp.float32)).astype(f.dtype))


def spectral_shift_attention_ns(q, k, v, c, scale=None, pinv_iters=8,
                                middle_form="eq8", add_shift_identity=True):
    """Spectral-shifting attention with the eq-11 iterative pseudoinverse
    and the matmul-only δ estimator — mirrors the Pallas/artifact path.
    """
    f, a, b = attention_factors(q, k, c, scale)
    a32 = a.astype(jnp.float32)
    z = ns_pinv_ord7(a32, iters=pinv_iters)
    delta = delta_ss_iterative(a32, z=z)
    eye = jnp.eye(c, dtype=jnp.float32)
    if middle_form == "eq8":
        mid = z @ (eye - delta * z)
    elif middle_form == "eq4":
        mid = z @ (eye - delta * a32)
    else:
        raise ValueError(middle_form)
    out = f @ (mid @ (b @ v).astype(jnp.float32)).astype(f.dtype)
    if add_shift_identity:
        out = out + delta.astype(out.dtype) * v
    return out


def delta_ss_iterative(a, z=None, iters=8, eps=1e-3):
    """Artifact-safe (matmul-only) spectral-shift parameter estimate.

      r̂ = tr(Z A)                        (ZA ≈ row-space projector ⇒ tr ≈ rank)
      δ̂ = max(0, (tr(A) − tr(Z A A)) / max(c − r̂, eps))

    Smoothly degenerates to δ=0 when A is numerically full rank (the
    numerator also vanishes there). This is the estimator lowered into the
    AOT artifacts; SVD-based ``delta_ss_exact`` is the test-time ground
    truth.
    """
    c = a.shape[0]
    if z is None:
        z = ns_pinv_ord7(a, iters)
    za = z @ a
    r_hat = jnp.trace(za)
    num = jnp.trace(a) - jnp.trace(za @ a)
    den = jnp.maximum(c - r_hat, eps)
    return jnp.maximum(num / den, 0.0).astype(a.dtype)
