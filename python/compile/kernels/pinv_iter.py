"""Pallas kernel: Newton-Schulz iterative pseudoinverse (paper sec 7, eq 11).

The c×c landmark block A_s is tiny (c ≤ 128 ⇒ 64 KiB at f32), so the whole
iteration runs fully VMEM-resident inside a single Pallas program — no
HBM round-trips between iterations. This is the piece that replaces the
SVD/LAPACK pseudoinverse in the AOT artifacts (matmul-only, so it lowers
to plain HLO the old xla_extension CPU runtime can execute).

Order-7 form (eq 11):  Z_{j+1} = ¼ Z_j (13I − AZ_j (15I − AZ_j (7I − AZ_j)))
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ns_pinv_pallas"]


def _ns_kernel(a_ref, z_ref, *, iters, order):
    a = a_ref[...].astype(jnp.float32)
    c = a.shape[0]
    eye = jnp.eye(c, dtype=jnp.float32)
    # Z0 = Aᵀ / (‖A‖₁ ‖A‖∞): satisfies the eq-11 convergence precondition
    # ‖A A⁺ − A Z₀‖ < 1 for any nonzero A.
    n1 = jnp.max(jnp.sum(jnp.abs(a), axis=0))
    ninf = jnp.max(jnp.sum(jnp.abs(a), axis=1))
    z0 = a.T / (n1 * ninf)

    if order == 7:
        def body(_, z):
            az = a @ z
            return 0.25 * z @ (13.0 * eye - az @ (15.0 * eye - az @ (7.0 * eye - az)))
    elif order == 3:
        def body(_, z):
            az = a @ z
            return z @ (3.0 * eye - az @ (3.0 * eye - az))
    else:
        raise ValueError(f"order must be 3 or 7, got {order}")

    z = jax.lax.fori_loop(0, iters, body, z0)
    z_ref[...] = z.astype(z_ref.dtype)


def ns_pinv_pallas(a, iters=8, order=7):
    """Iterative pseudoinverse of a (c, c) matrix, fully VMEM-resident."""
    c, c2 = a.shape
    if c != c2:
        raise ValueError(f"A must be square, got {a.shape}")
    kernel = functools.partial(_ns_kernel, iters=iters, order=order)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((c, c), a.dtype),
        interpret=True,
    )(a)
