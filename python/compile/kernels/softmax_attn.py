"""Pallas kernel: exact softmax self-attention, flash-style blocking.

This is the O(n²) ground-truth attention (paper sec 2.1) used as the
baseline row of Table 1 and as the oracle target in serving comparisons.

TPU mapping: the grid tiles the query axis (block_q rows per step); keys
and values stream through the kernel in block_k chunks with the standard
online-softmax recurrence (running max m, running normalizer l, running
accumulator acc), so peak VMEM is
  block_q·d + 2·block_k·d + block_q·block_k + block_q·dv  floats
instead of n² — the Pallas analogue of the CUDA shared-memory staging a
GPU flash kernel would do with threadblocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["softmax_attention_pallas"]


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k):
    q = q_ref[...].astype(jnp.float32)  # (bq, d)
    k = k_ref[...].astype(jnp.float32)  # (n, d) — streamed in bk chunks below
    v = v_ref[...].astype(jnp.float32)  # (n, dv)
    bq = q.shape[0]
    n = k.shape[0]
    dv = v.shape[1]
    nk = n // block_k

    def body(i, carry):
        m_prev, l_prev, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, 0)
        vc = jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, 0)
        s = (q @ kc.T) * scale                           # (bq, bk)
        m_cur = jnp.max(s, axis=-1)                      # (bq,)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])                  # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                   # (bq,)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ vc
        return m_new, l_new, acc

    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dv), jnp.float32)
    _, l_fin, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[...] = (acc / l_fin[:, None]).astype(o_ref.dtype)


def softmax_attention_pallas(q, k, v, scale=None, block_q=128, block_k=128):
    """Exact attention softmax(q kᵀ · scale) v via a blocked Pallas kernel.

    q: (n, d), k: (m, d), v: (m, dv) -> (n, dv). n must divide by block_q
    and m by block_k (callers pad; the L2 model always uses powers of two).
    """
    n, d = q.shape
    m, dv = v.shape
    block_q = min(block_q, n)
    block_k = min(block_k, m)
    if n % block_q or m % block_k:
        raise ValueError(f"n={n} % block_q={block_q} or m={m} % block_k={block_k} != 0")
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_flash_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, dv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, dv), q.dtype),
        interpret=True,
    )(q, k, v)
