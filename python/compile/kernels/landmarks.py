"""Pallas kernel: segment-means landmark selection (paper sec 2.3, eq 1).

TPU mapping: one grid step per landmark segment; each step stages an
(l, d) row-block of the input in VMEM and reduces it to a single (1, d)
mean row. l·d·4 bytes per step (e.g. 64·64·4 = 16 KiB) — far below the
16 MiB VMEM budget, so the HBM↔VMEM schedule is a single streaming pass.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_means_pallas", "segment_means_pair_pallas"]


def _segment_mean_kernel(x_ref, o_ref, *, l):
    # x_ref: (cb·l, d) block of cb whole segments; o_ref: (cb, d).
    block = x_ref[...].astype(jnp.float32)
    cb = block.shape[0] // l
    means = block.reshape(cb, l, block.shape[1]).mean(axis=1)
    o_ref[...] = means.astype(o_ref.dtype)


def segment_means_pallas(x, c, segments_per_step=None):
    """Segment-means landmarks: (n, d) -> (c, d), n divisible by c.

    ``segments_per_step`` controls the grid granularity: each grid step
    reduces that many whole segments (VMEM per step = spb·l·d·4 bytes).
    Default: all c segments in one step when the input fits the 16 MiB
    VMEM budget (always true for this model family — n·d ≤ 512·256), else
    one segment per step. Grid-step count is the dominant cost on the
    interpret/CPU path (§Perf), so fewer, fatter steps win there too.
    """
    n, d = x.shape
    if n % c != 0:
        raise ValueError(f"n={n} not divisible by c={c}")
    l = n // c
    if segments_per_step is None:
        segments_per_step = c if n * d * 4 <= 16 << 20 else 1
    if c % segments_per_step != 0:
        raise ValueError(f"c={c} not divisible by segments_per_step={segments_per_step}")
    spb = segments_per_step
    kernel = functools.partial(_segment_mean_kernel, l=l)
    return pl.pallas_call(
        kernel,
        grid=(c // spb,),
        in_specs=[pl.BlockSpec((spb * l, d), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((spb, d), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((c, d), x.dtype),
        interpret=True,
    )(x)


def _segment_mean_pair_kernel(q_ref, k_ref, qt_ref, kt_ref, *, l):
    # both (n, d) inputs reduced in one program — halves the per-call
    # overhead on the interpret/CPU path (§Perf change #4)
    for src, dst in ((q_ref, qt_ref), (k_ref, kt_ref)):
        block = src[...].astype(jnp.float32)
        c = block.shape[0] // l
        dst[...] = block.reshape(c, l, block.shape[1]).mean(axis=1).astype(dst.dtype)


def segment_means_pair_pallas(q, k, c):
    """Fused landmark selection for a (q, k) pair: one Pallas call
    producing both Q̃ and K̃. Same math as two `segment_means_pallas`
    calls; used by the attention variants on the model path."""
    n, d = q.shape
    if q.shape != k.shape:
        raise ValueError(f"q{q.shape} vs k{k.shape}")
    if n % c != 0:
        raise ValueError(f"n={n} not divisible by c={c}")
    kernel = functools.partial(_segment_mean_pair_kernel, l=n // c)
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((c, d), q.dtype),
                   jax.ShapeDtypeStruct((c, d), k.dtype)),
        interpret=True,
    )(q, k)
