"""Layer-1 Pallas kernels for ssaformer.

Public surface:
  segment_means_pallas            — landmark selection (paper eq 1)
  softmax_attention_pallas        — exact flash-style attention (sec 2.1)
  landmark_cross_attention_pallas — streamed B·V factor (sec 2.4/5)
  ns_pinv_pallas                  — eq (11) iterative pseudoinverse
  spectral_shift_attention_pallas — the paper's contribution (sec 5)
  nystrom_attention_pallas        — Nystromformer baseline (sec 2.4)
  ref                             — pure-jnp correctness oracles
"""

from . import ref
from .cross_attn import landmark_cross_attention_pallas
from .landmarks import segment_means_pallas
from .pinv_iter import ns_pinv_pallas
from .softmax_attn import softmax_attention_pallas
from .spectral_shift import (
    nystrom_attention_pallas,
    spectral_shift_attention_pallas,
    ss_middle_factor,
)

__all__ = [
    "ref",
    "segment_means_pallas",
    "softmax_attention_pallas",
    "landmark_cross_attention_pallas",
    "ns_pinv_pallas",
    "spectral_shift_attention_pallas",
    "nystrom_attention_pallas",
    "ss_middle_factor",
]
