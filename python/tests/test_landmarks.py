"""segment_means_pallas vs the pure-jnp oracle (paper eq 1)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, segment_means_pallas
from .conftest import make_qkv


@pytest.mark.parametrize("n,c,d", [(64, 8, 16), (128, 32, 64), (256, 64, 32),
                                   (96, 12, 8), (512, 64, 64)])
def test_matches_ref(rng, n, c, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    got = segment_means_pallas(jnp.asarray(x), c)
    want = ref.segment_means(jnp.asarray(x), c)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_rejects_indivisible(rng):
    x = jnp.asarray(rng.normal(size=(100, 8)), jnp.float32)
    with pytest.raises(ValueError):
        segment_means_pallas(x, 7)
    with pytest.raises(ValueError):
        ref.segment_means(x, 7)


def test_constant_input_gives_constant_landmarks():
    x = jnp.full((64, 4), 3.5, jnp.float32)
    out = segment_means_pallas(x, 8)
    np.testing.assert_allclose(out, np.full((8, 4), 3.5), rtol=1e-6)


def test_segment_structure(rng):
    """Each landmark must equal the mean of exactly its own segment."""
    n, c, d = 64, 4, 8
    x = rng.normal(size=(n, d)).astype(np.float32)
    out = np.asarray(segment_means_pallas(jnp.asarray(x), c))
    l = n // c
    for j in range(c):
        np.testing.assert_allclose(out[j], x[j * l:(j + 1) * l].mean(0),
                                   rtol=1e-5, atol=1e-6)


def test_c_equals_n_is_identity(rng):
    x = rng.normal(size=(32, 8)).astype(np.float32)
    out = segment_means_pallas(jnp.asarray(x), 32)
    np.testing.assert_allclose(out, x, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    logn=st.integers(3, 8),
    logc=st.integers(0, 4),
    d=st.sampled_from([1, 3, 8, 17, 64]),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_hypothesis_shapes(logn, logc, d, dtype):
    n = 2 ** logn
    c = 2 ** min(logc, logn)
    rng = np.random.default_rng(logn * 100 + logc * 10 + d)
    x = rng.normal(size=(n, d)).astype(dtype)
    got = np.asarray(segment_means_pallas(jnp.asarray(x), c))
    want = x.reshape(c, n // c, d).mean(1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(logn=st.integers(3, 7))
def test_hypothesis_bf16(logn):
    n = 2 ** logn
    rng = np.random.default_rng(logn)
    x = jnp.asarray(rng.normal(size=(n, 16)), jnp.bfloat16)
    got = np.asarray(segment_means_pallas(x, 4), np.float32)
    want = np.asarray(ref.segment_means(x.astype(jnp.float32), 4))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_segments_per_step_equivalent(rng):
    """Any grid granularity must give identical landmarks."""
    from compile.kernels.landmarks import segment_means_pallas
    x = jnp.asarray(rng.normal(size=(128, 16)), jnp.float32)
    base = segment_means_pallas(x, 16, segments_per_step=1)
    for spb in (2, 4, 8, 16):
        got = segment_means_pallas(x, 16, segments_per_step=spb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-6, atol=1e-6)


def test_segments_per_step_must_divide(rng):
    from compile.kernels.landmarks import segment_means_pallas
    x = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    with pytest.raises(ValueError):
        segment_means_pallas(x, 8, segments_per_step=3)


def test_pair_kernel_matches_two_calls(rng):
    """The fused q/k landmark kernel (§Perf change 4) must equal two
    independent segment-means calls."""
    from compile.kernels.landmarks import (
        segment_means_pair_pallas, segment_means_pallas)
    q = jnp.asarray(rng.normal(size=(96, 12)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(96, 12)), jnp.float32)
    qt, kt = segment_means_pair_pallas(q, k, 8)
    np.testing.assert_allclose(np.asarray(qt),
                               np.asarray(segment_means_pallas(q, 8)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(kt),
                               np.asarray(segment_means_pallas(k, 8)),
                               rtol=1e-6, atol=1e-6)


def test_pair_kernel_shape_mismatch(rng):
    from compile.kernels.landmarks import segment_means_pair_pallas
    q = jnp.zeros((64, 8), jnp.float32)
    k = jnp.zeros((32, 8), jnp.float32)
    with pytest.raises(ValueError):
        segment_means_pair_pallas(q, k, 8)
