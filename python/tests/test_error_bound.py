"""Paper sec 7: the error bound (eq 12) and its ingredients.

  E ≤ 1 + ‖A⁺‖∞ (1 + δ‖A⁺‖∞)(1 − ‖A⁺ − Z*‖∞)

measured with E = ‖S − S̃‖∞ row-sum norm as in the paper's proof chain.
The bound as printed is loose (it bounds by a SUM of norms, each ≤ its
factor); we verify it holds empirically and track its tightness in the
error_bound bench (E5).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from .conftest import make_qkv


def _inf_norm(m):
    return float(np.max(np.sum(np.abs(np.asarray(m)), axis=1)))


@pytest.mark.parametrize("n,c", [(128, 16), (256, 32)])
def test_eq12_bound_holds(rng, n, c):
    q, k, v = make_qkv(rng, n, 32)
    qj, kj = jnp.asarray(q), jnp.asarray(k)
    scale = 1.0 / np.sqrt(32)
    s_true = jax.nn.softmax((qj @ kj.T) * scale, axis=-1)
    s_apx = ref.spectral_shift_matrix(qj, kj, c)
    e = _inf_norm(s_true - s_apx)

    _, a, _ = ref.attention_factors(qj, kj, c)
    pinv = jnp.linalg.pinv(a)
    z = ref.ns_pinv_ord7(a, iters=20)
    delta = float(ref.delta_ss_exact(a))
    napx = _inf_norm(pinv)
    nzdiff = _inf_norm(pinv - z)
    bound = 1.0 + napx * (1.0 + delta * napx) * max(1.0 - nzdiff, 0.0)
    # eq 12's RHS as printed; E must not exceed it when Z* has converged
    assert e <= bound + 1e-3, (e, bound)


def test_softmax_rows_inf_norm_is_one(rng):
    """Step (c) of the proof: ‖L(A)‖∞ = 1 for any row-softmax matrix."""
    q, k, _ = make_qkv(rng, 64, 16)
    s = jax.nn.softmax(jnp.asarray(q) @ jnp.asarray(k).T / 4.0, axis=-1)
    assert abs(_inf_norm(s) - 1.0) < 1e-5


def test_error_decreases_with_c(rng):
    """More landmarks ⇒ lower approximation error (monotone in trend)."""
    q, k, v = make_qkv(rng, 256, 32, scale=0.5)
    qj, kj = jnp.asarray(q), jnp.asarray(k)
    s_true = jax.nn.softmax((qj @ kj.T) / np.sqrt(32), axis=-1)
    errs = []
    for c in (8, 32, 128):
        s_apx = ref.spectral_shift_matrix(qj, kj, c)
        errs.append(float(jnp.linalg.norm(s_true - s_apx) /
                          jnp.linalg.norm(s_true)))
    assert errs[-1] < errs[0], errs


def test_ss_at_least_as_good_as_nystrom_fro(rng):
    """Theorem-1 flavour on the attention matrix: with a coarse rank
    tolerance (making δ>0 meaningful) the SS matrix error should not be
    materially worse than Nystrom's, and is strictly better on the
    sampled block."""
    q, k, _ = make_qkv(rng, 192, 16, scale=2.0)
    qj, kj = jnp.asarray(q), jnp.asarray(k)
    c = 24
    scale = 1.0 / np.sqrt(16)
    s_true = jax.nn.softmax((qj @ kj.T) * scale, axis=-1)
    f, a, b = ref.attention_factors(qj, kj, c)
    s_ny = f @ jnp.linalg.pinv(a) @ b
    s_ss = ref.spectral_shift_matrix(qj, kj, c, rank_rtol=1e-2)
    e_ny = float(jnp.linalg.norm(s_true - s_ny))
    e_ss = float(jnp.linalg.norm(s_true - s_ss))
    assert e_ss <= e_ny * 1.25, (e_ss, e_ny)
