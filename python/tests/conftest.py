"""Shared fixtures/utilities for the ssaformer python test suite."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def make_qkv(rng, n, d, dv=None, dtype=np.float32, scale=1.0):
    """Gaussian q, k, v test tensors."""
    dv = dv or d
    q = (rng.normal(size=(n, d)) * scale).astype(dtype)
    k = (rng.normal(size=(n, d)) * scale).astype(dtype)
    v = (rng.normal(size=(n, dv)) * scale).astype(dtype)
    return q, k, v
