"""Flash-style exact attention kernel vs the jnp oracle (paper sec 2.1)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, softmax_attention_pallas
from .conftest import make_qkv


@pytest.mark.parametrize("n,d", [(64, 16), (128, 64), (256, 32), (512, 64)])
@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_matches_ref(rng, n, d, bq, bk):
    if n % bq or n % bk:
        pytest.skip("block must divide n")
    q, k, v = make_qkv(rng, n, d)
    got = softmax_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), block_q=bq, block_k=bk)
    want = ref.softmax_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_block_size_invariance(rng):
    """Output must be identical (up to fp assoc) across blockings."""
    q, k, v = make_qkv(rng, 256, 32)
    outs = [np.asarray(softmax_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), block_q=bq, block_k=bk))
        for bq, bk in [(32, 32), (64, 64), (128, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-5)


def test_rows_are_convex_combinations(rng):
    """softmax rows sum to 1 ⇒ outputs lie inside the convex hull of v."""
    q, k, v = make_qkv(rng, 128, 16)
    out = np.asarray(softmax_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                              jnp.asarray(v)))
    assert out.min() >= v.min() - 1e-4
    assert out.max() <= v.max() + 1e-4


def test_large_logits_stable(rng):
    """Online-softmax must survive large score magnitudes (no inf/nan)."""
    q, k, v = make_qkv(rng, 128, 16, scale=30.0)
    out = np.asarray(softmax_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                              jnp.asarray(v)))
    assert np.isfinite(out).all()
    want = np.asarray(ref.softmax_attention(jnp.asarray(q), jnp.asarray(k),
                                            jnp.asarray(v)))
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-4)


def test_custom_scale(rng):
    q, k, v = make_qkv(rng, 64, 8)
    got = softmax_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), scale=0.25)
    want = ref.softmax_attention(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), scale=0.25)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_rejects_bad_blocking(rng):
    q, k, v = make_qkv(rng, 96, 8)
    with pytest.raises(ValueError):
        softmax_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                 jnp.asarray(v), block_q=64, block_k=64)


@settings(max_examples=12, deadline=None)
@given(
    logn=st.integers(4, 9),
    d=st.sampled_from([8, 16, 64]),
    dv=st.sampled_from([8, 32]),
    dtype=st.sampled_from(["f32", "bf16"]),
)
def test_hypothesis_shapes_dtypes(logn, d, dv, dtype):
    n = 2 ** logn
    rng = np.random.default_rng(n + d + dv)
    q, k, v = make_qkv(rng, n, d, dv=dv)
    if dtype == "bf16":
        qj, kj, vj = (jnp.asarray(x, jnp.bfloat16) for x in (q, k, v))
        tol = dict(rtol=3e-2, atol=3e-2)
    else:
        qj, kj, vj = (jnp.asarray(x) for x in (q, k, v))
        tol = dict(rtol=3e-4, atol=3e-5)
    got = np.asarray(softmax_attention_pallas(qj, kj, vj), np.float32)
    want = np.asarray(ref.softmax_attention(
        qj.astype(jnp.float32), kj.astype(jnp.float32),
        vj.astype(jnp.float32)), np.float32)
    np.testing.assert_allclose(got, want, **tol)
