"""Newton-Schulz iterative pseudoinverse (paper sec 7 eq 11) tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ns_pinv_pallas, ref


def _softmax_block(rng, c, d=32):
    q = rng.normal(size=(c, d)).astype(np.float32)
    k = rng.normal(size=(c, d)).astype(np.float32)
    return np.asarray(jax.nn.softmax(q @ k.T / np.sqrt(d), axis=-1))


def test_pallas_matches_ref_iteration(rng):
    a = jnp.asarray(_softmax_block(rng, 32))
    got = ns_pinv_pallas(a, iters=8, order=7)
    want = ref.ns_pinv_ord7(a, iters=8)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pallas_ord3_matches_ref(rng):
    a = jnp.asarray(_softmax_block(rng, 16))
    got = ns_pinv_pallas(a, iters=12, order=3)
    want = ref.ns_pinv_ord3(a, iters=12)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_converges_to_inverse_well_conditioned(rng):
    """A + I is well conditioned: few ord-7 iterations reach machine eps."""
    c = 24
    a = jnp.asarray(_softmax_block(rng, c) + np.eye(c, dtype=np.float32))
    z = ns_pinv_pallas(a, iters=6, order=7)
    np.testing.assert_allclose(np.asarray(a @ z), np.eye(c), atol=1e-4)


def test_converges_on_softmax_block(rng):
    """Landmark softmax blocks (cond ~1e3-1e4) converge by ~20 iterations."""
    a = jnp.asarray(_softmax_block(rng, 32))
    z = ns_pinv_pallas(a, iters=24, order=7)
    resid = float(jnp.max(jnp.abs(a @ z - jnp.eye(32))))
    assert resid < 1e-3, resid


def test_rank_deficient_converges_to_pinv(rng):
    """On singular SPSD input NS converges to the Moore-Penrose pinv on
    the range space. NOTE: in f32 the iteration converges and then
    DIVERGES (rounding noise in the null space gets inverted once
    amplified past σ_min ≈ eps), so we stop at 8 iterations — the
    converged regime. The divergence itself is asserted below."""
    c, r = 16, 5
    u = np.linalg.qr(rng.normal(size=(c, c)))[0][:, :r].astype(np.float32)
    lam = np.linspace(2.0, 1.0, r).astype(np.float32)
    a = jnp.asarray(u @ np.diag(lam) @ u.T)
    z = ns_pinv_pallas(a, iters=8, order=7)
    want = jnp.linalg.pinv(a)
    np.testing.assert_allclose(np.asarray(z), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_rank_deficient_overiteration_diverges(rng):
    """Documents the finite-precision failure mode that motivates the
    fixed small iteration count used in the artifacts: on singular input,
    over-iterating NS in f32 amplifies null-space rounding noise."""
    c, r = 16, 5
    u = np.linalg.qr(rng.normal(size=(c, c)))[0][:, :r].astype(np.float32)
    lam = np.linspace(2.0, 1.0, r).astype(np.float32)
    a = jnp.asarray(u @ np.diag(lam) @ u.T)
    z30 = ns_pinv_pallas(a, iters=30, order=7)
    err30 = float(jnp.max(jnp.abs(z30 - jnp.linalg.pinv(a))))
    assert err30 > 1.0, "expected f32 divergence on singular input"


def test_ord7_faster_than_ord3(rng):
    """Same residual with ~3x fewer iterations (7th vs 3rd order)."""
    c = 24
    a = jnp.asarray(_softmax_block(rng, c) + 0.1 * np.eye(c, dtype=np.float32))
    eye = jnp.eye(c)
    def resid(z):
        return float(jnp.max(jnp.abs(a @ z - eye)))
    r7 = resid(ref.ns_pinv_ord7(a, iters=6))
    r3 = resid(ref.ns_pinv_ord3(a, iters=6))
    assert r7 < r3


def test_ns_init_satisfies_precondition(rng):
    """‖I − A Z₀‖₂ < 1 must hold for the scaled-transpose init."""
    for c in (8, 16, 48):
        a = _softmax_block(rng, c)
        z0 = np.asarray(ref.ns_init(jnp.asarray(a)))
        s = np.linalg.svd(np.eye(c) - a @ z0, compute_uv=False)
        assert s[0] < 1.0 + 1e-6


def test_delta_iterative_matches_exact_on_deficient(rng):
    """On a matrix with a genuinely flat discarded tail the iterative δ̂
    approaches the SVD-exact δ."""
    c, r, theta = 32, 6, 0.05
    u = np.linalg.qr(rng.normal(size=(c, c)))[0].astype(np.float32)
    lam = np.concatenate([np.linspace(3, 2, r), np.full(c - r, theta)]).astype(np.float32)
    a = jnp.asarray(u @ np.diag(lam) @ u.T)
    # rank tolerance chosen between theta and the spike block
    d_exact = float(ref.delta_ss_exact(a, rank_rtol=0.1))
    assert abs(d_exact - theta) < 2e-2, d_exact


@settings(max_examples=10, deadline=None)
@given(c=st.sampled_from([4, 8, 16, 32, 64]), seed=st.integers(0, 100))
def test_hypothesis_pallas_ref_agree(c, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(_softmax_block(rng, c))
    got = np.asarray(ns_pinv_pallas(a, iters=6, order=7))
    want = np.asarray(ref.ns_pinv_ord7(a, iters=6))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
