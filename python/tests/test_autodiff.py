"""custom_vjp wrappers: pallas forward, jnp-ref backward."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.kernels.autodiff import (
    nystrom_attention_ad,
    softmax_attention_ad,
    spectral_shift_attention_ad,
)
from .conftest import make_qkv


def test_softmax_forward_is_pallas_value(rng):
    q, k, v = make_qkv(rng, 64, 16)
    got = softmax_attention_ad(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                               block_q=32, block_k=32)
    want = ref.softmax_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_softmax_grad_matches_ref_grad(rng):
    q, k, v = make_qkv(rng, 64, 16)
    qj, kj, vj = (jnp.asarray(x) for x in (q, k, v))

    def loss_ad(q, k, v):
        return jnp.sum(softmax_attention_ad(q, k, v, block_q=32, block_k=32) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.softmax_attention(q, k, v) ** 2)

    g_ad = jax.grad(loss_ad, argnums=(0, 1, 2))(qj, kj, vj)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(qj, kj, vj)
    for a, b in zip(g_ad, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("fn,reffn", [
    (lambda q, k, v: nystrom_attention_ad(q, k, v, 16, block_q=64, block_k=64),
     lambda q, k, v: ref.nystrom_attention_ns(q, k, v, 16)),
    (lambda q, k, v: spectral_shift_attention_ad(q, k, v, 16, block_q=64, block_k=64),
     lambda q, k, v: ref.spectral_shift_attention_ns(q, k, v, 16)),
])
def test_linear_variants_grads(rng, fn, reffn):
    q, k, v = make_qkv(rng, 128, 16)
    qj, kj, vj = (jnp.asarray(x) for x in (q, k, v))
    g_ad = jax.grad(lambda q, k, v: jnp.sum(fn(q, k, v) ** 2),
                    argnums=(0, 1, 2))(qj, kj, vj)
    g_ref = jax.grad(lambda q, k, v: jnp.sum(reffn(q, k, v) ** 2),
                     argnums=(0, 1, 2))(qj, kj, vj)
    for a, b in zip(g_ad, g_ref):
        assert np.isfinite(np.asarray(a)).all()
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_vmap_over_heads(rng):
    """The L2 model folds (batch, heads) into one vmap axis — the wrappers
    must batch correctly."""
    bh, n, d = 6, 64, 8
    q = jnp.asarray(rng.normal(size=(bh, n, d)), jnp.float32)
    out = jax.vmap(lambda x: spectral_shift_attention_ad(
        x, x, x, 8, block_q=32, block_k=32))(q)
    assert out.shape == (bh, n, d)
    one = spectral_shift_attention_ad(q[2], q[2], q[2], 8,
                                      block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(one),
                               rtol=1e-5, atol=1e-6)


def test_grad_through_vmap(rng):
    bh, n, d = 4, 64, 8
    q = jnp.asarray(rng.normal(size=(bh, n, d)), jnp.float32)

    def loss(q):
        out = jax.vmap(lambda x: nystrom_attention_ad(
            x, x, x, 8, block_q=32, block_k=32))(q)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(q)
    assert g.shape == q.shape
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.max(jnp.abs(g))) > 0
