"""L2 model: layout, forward shapes, loss behaviour, Adam step."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def tiny_cfg(attention="ss"):
    return M.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2,
                         d_ff=64, seq_len=32, attention=attention,
                         landmarks=8, pinv_iters=6,
                         block_q=32, block_k=32).validate()


def test_layout_covers_vector_exactly():
    cfg = tiny_cfg()
    lay = M._layout(cfg)
    sizes = sum(int(np.prod(s)) for _, s in lay.entries)
    assert sizes == lay.total == M.count_params(cfg)
    # offsets are contiguous & non-overlapping
    off = 0
    for name, shape in lay.entries:
        o, s = lay.offsets[name]
        assert o == off and s == shape
        off += int(np.prod(shape))


def test_layout_slice_roundtrip():
    cfg = tiny_cfg()
    lay = M._layout(cfg)
    flat = jnp.arange(lay.total, dtype=jnp.float32)
    w = lay.slice(flat, "layer1.wq")
    o, shape = lay.offsets["layer1.wq"]
    np.testing.assert_array_equal(
        np.asarray(w).ravel(), np.arange(o, o + int(np.prod(shape))))


def test_init_params_stats():
    cfg = tiny_cfg()
    flat = M.init_params(cfg, seed=0)
    lay = M._layout(cfg)
    o, s = lay.offsets["layer0.ln1_g"]
    np.testing.assert_array_equal(flat[o:o + 32], np.ones(32, np.float32))
    o, s = lay.offsets["layer0.wq"]
    w = flat[o:o + 32 * 32]
    assert 0.5 / np.sqrt(32) < w.std() < 2.0 / np.sqrt(32)


def test_init_deterministic():
    cfg = tiny_cfg()
    np.testing.assert_array_equal(M.init_params(cfg, 7), M.init_params(cfg, 7))
    assert not np.array_equal(M.init_params(cfg, 7), M.init_params(cfg, 8))


@pytest.mark.parametrize("attention", ["full", "nystrom", "ss"])
def test_forward_shapes(attention):
    cfg = tiny_cfg(attention)
    flat = jnp.asarray(M.init_params(cfg, 0))
    tokens = jnp.zeros((3, cfg.seq_len), jnp.int32)
    h = M.forward(cfg, flat, tokens)
    assert h.shape == (3, cfg.seq_len, cfg.d_model)
    emb = M.encode_fn(cfg, flat, tokens)
    assert emb.shape == (3, cfg.d_model)
    logits = M.logits_fn(cfg, flat, tokens)
    assert logits.shape == (3, cfg.seq_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform():
    cfg = tiny_cfg()
    flat = jnp.asarray(M.init_params(cfg, 0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, cfg.seq_len)), jnp.int32)
    mask = jnp.ones((4, cfg.seq_len), jnp.float32)
    loss = M.loss_fn(cfg, flat, tokens, tokens, mask)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_loss_mask_zero_positions_ignored():
    cfg = tiny_cfg()
    flat = jnp.asarray(M.init_params(cfg, 0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.seq_len)), jnp.int32)
    bad_targets = (tokens + 13) % cfg.vocab
    mask_first = jnp.concatenate(
        [jnp.ones((2, 1)), jnp.zeros((2, cfg.seq_len - 1))], axis=1)
    l1 = M.loss_fn(cfg, flat, tokens, bad_targets, mask_first)
    # changing masked-out targets must not change the loss
    worse = bad_targets.at[:, 1:].set(0)
    l2 = M.loss_fn(cfg, flat, tokens, worse, mask_first)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


@pytest.mark.parametrize("attention", ["full", "ss"])
def test_train_step_reduces_loss(attention):
    """A few Adam steps on a fixed batch must reduce the loss."""
    cfg = tiny_cfg(attention)
    flat = jnp.asarray(M.init_params(cfg, 0))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, cfg.seq_len)), jnp.int32)
    mask = jnp.ones((4, cfg.seq_len), jnp.float32)
    step_fn = jax.jit(lambda p, m, v, s: M.train_step_fn(
        cfg, p, m, v, s, tokens, tokens, mask))
    losses = []
    for s in range(1, 13):
        flat, m, v, loss = step_fn(flat, m, v, jnp.float32(s))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses


def test_train_step_finite_updates():
    cfg = tiny_cfg()
    flat = jnp.asarray(M.init_params(cfg, 0))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.seq_len)), jnp.int32)
    mask = jnp.ones((2, cfg.seq_len), jnp.float32)
    p2, m2, v2, loss = M.train_step_fn(cfg, flat, m, v, jnp.float32(1),
                                       tokens, tokens, mask)
    for arr in (p2, m2, v2):
        assert np.isfinite(np.asarray(arr)).all()
    assert float(jnp.max(jnp.abs(p2 - flat))) > 0
    # Adam first-step magnitude ≈ lr
    assert float(jnp.max(jnp.abs(p2 - flat))) < 10 * cfg.lr


def test_config_validation():
    with pytest.raises(ValueError):
        M.ModelConfig(attention="fancy").validate()
    with pytest.raises(ValueError):
        M.ModelConfig(attention="ss", seq_len=100, landmarks=32).validate()
