"""The paper's contribution: modified spectral-shifting attention (sec 4-5).

Covers: pallas-vs-oracle agreement, the eq4/eq8 middle-factor variants,
the δIₙ add-back, δ estimators, Lemma 1 / Theorem 1 exact recovery, and
the Figure-2 spectrum property (no long low-rank tail).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    nystrom_attention_pallas,
    ref,
    spectral_shift_attention_pallas,
)
from .conftest import make_qkv


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,c,d", [(128, 16, 32), (256, 32, 64), (512, 64, 32)])
def test_ss_pallas_matches_ns_ref(rng, n, c, d):
    q, k, v = make_qkv(rng, n, d)
    got = spectral_shift_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                          jnp.asarray(v), c)
    want = ref.spectral_shift_attention_ns(jnp.asarray(q), jnp.asarray(k),
                                           jnp.asarray(v), c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,c,d", [(128, 16, 32), (256, 32, 64)])
def test_nystrom_pallas_matches_ns_ref(rng, n, c, d):
    q, k, v = make_qkv(rng, n, d)
    got = nystrom_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), c)
    want = ref.nystrom_attention_ns(jnp.asarray(q), jnp.asarray(k),
                                    jnp.asarray(v), c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_converged_pinv_matches_svd_ref(rng):
    """With enough NS iterations the kernel path reproduces the SVD-pinv
    reference (ties the iterative implementation to the paper's math).

    Gaussian q,k give landmark blocks with seed-dependent condition
    numbers up to ~1e5, where f32 NS needs 25+ iterations (see
    test_pinv); to test *implementation equivalence at convergence* we
    construct segment-aligned q,k so A_s is diagonally dominant and
    well-conditioned by design."""
    n, c, d = 128, 16, 32
    l = n // c
    basis = np.zeros((c, d), np.float32)
    basis[np.arange(c), np.arange(c)] = 2.0  # segment j ↦ 2·e_j
    noise = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
    q = np.repeat(basis, l, axis=0) + 0.2 * noise
    k = np.repeat(basis, l, axis=0) + 0.2 * noise[::-1]
    v = np.random.default_rng(1).normal(size=(n, d)).astype(np.float32)
    got = spectral_shift_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                          jnp.asarray(v), c, pinv_iters=12)
    want = ref.spectral_shift_attention(jnp.asarray(q), jnp.asarray(k),
                                        jnp.asarray(v), c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("middle_form", ["eq8", "eq4"])
@pytest.mark.parametrize("add_id", [True, False])
def test_variant_flags(rng, middle_form, add_id):
    q, k, v = make_qkv(rng, 128, 16, 16)
    got = spectral_shift_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), 16,
        middle_form=middle_form, add_shift_identity=add_id)
    want = ref.spectral_shift_attention_ns(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), 16,
        middle_form=middle_form, add_shift_identity=add_id)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_bad_middle_form(rng):
    q, k, v = make_qkv(rng, 64, 8)
    with pytest.raises(ValueError):
        spectral_shift_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                        jnp.asarray(v), 8, middle_form="eq5")


# ---------------------------------------------------------------------------
# semantics of the approximation
# ---------------------------------------------------------------------------


def test_delta_zero_reduces_to_nystrom(rng):
    """When A_s is numerically full rank δ̂≈0 and SS ≡ Nystromformer."""
    q, k, v = make_qkv(rng, 128, 16)
    ss = spectral_shift_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                         jnp.asarray(v), 16)
    ny = nystrom_attention_pallas(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), 16)
    # δ̂ is tiny but nonzero (unconverged pinv) — outputs nearly equal
    np.testing.assert_allclose(np.asarray(ss), np.asarray(ny),
                               rtol=5e-2, atol=5e-2)


def test_close_to_exact_attention_large_c(rng):
    """With c = n/2 landmarks the approximation should track exact
    attention closely (sanity bound, not a paper claim)."""
    q, k, v = make_qkv(rng, 128, 32, scale=0.5)
    approx = spectral_shift_attention_pallas(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), 64, pinv_iters=24)
    exact = ref.softmax_attention(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v))
    err = float(jnp.mean(jnp.abs(approx - exact)) / jnp.mean(jnp.abs(exact)))
    assert err < 0.5, err


# ---------------------------------------------------------------------------
# Lemma 1 / Theorem 1: exact recovery on spike+flat-tail SPSD matrices
# ---------------------------------------------------------------------------


def _spiked_spsd(rng, n, kspikes, theta):
    """SPSD K with λ₁..λ_k spikes > θ and a perfectly flat θ tail."""
    u = np.linalg.qr(rng.normal(size=(n, n)))[0].astype(np.float64)
    lam = np.concatenate([np.linspace(5.0, 3.0, kspikes),
                          np.full(n - kspikes, theta)])
    return (u * lam) @ u.T


def _ss_spsd_approx(kmat, cols, rank_rtol):
    """Full SS model on an explicit SPSD matrix using column selection P
    (paper sec 4 closed form, C = K P, A_s = Pᵀ K P)."""
    c_sub = kmat[:, cols]
    a_s = kmat[np.ix_(cols, cols)]
    s = np.linalg.svd(a_s, compute_uv=False)
    r = int((s > rank_rtol * s[0]).sum())
    pinv = np.linalg.pinv(a_s, rcond=rank_rtol)
    delta = 0.0
    if len(cols) - r > 0:
        delta = (np.trace(a_s) - np.trace(pinv @ a_s @ a_s)) / (len(cols) - r)
    pinv2 = np.linalg.pinv(a_s @ a_s, rcond=rank_rtol)
    u_ss = pinv - delta * pinv2
    return c_sub @ u_ss @ c_sub.T + delta * np.eye(kmat.shape[0]), delta


def _nystrom_spsd_approx(kmat, cols):
    c_sub = kmat[:, cols]
    a_s = kmat[np.ix_(cols, cols)]
    return c_sub @ np.linalg.pinv(a_s) @ c_sub.T


def test_theorem1_exact_recovery(rng):
    """Lemma 1: with the spike space inside the sampled columns' span and
    δ capturing the flat tail, ‖K − K̃ˢˢ‖ ≈ 0 while Nystrom keeps Θ(θ)
    error. We shift by δ=θ: K−θI has rank k, so ANY c≥k independent
    columns span it (the paper's near-optimal sampling achieves this)."""
    n, kspikes, theta = 96, 6, 0.5
    kmat = _spiked_spsd(rng, n, kspikes, theta)
    cols = list(range(0, n, n // 16))  # c=16 ≥ k=6 columns
    # spectral shifting on the shifted matrix K̃ = K − θ Iₙ (sec 3: K−δI)
    kshift = kmat - theta * np.eye(n)
    approx_lowrank, _ = _ss_spsd_approx(kshift, cols, rank_rtol=1e-8)
    # K̃ is exactly rank k ⇒ the prototype part alone recovers it; add tail back
    approx = approx_lowrank + theta * np.eye(n)
    err_ss = np.linalg.norm(kmat - approx, 2)
    err_ny = np.linalg.norm(kmat - _nystrom_spsd_approx(kmat, cols), 2)
    assert err_ss < 1e-6 * np.linalg.norm(kmat, 2), err_ss
    assert err_ny > 0.1 * theta, err_ny  # Nystrom cannot represent the tail


def test_modified_ss_objective_zero_on_sampled_block(rng):
    """Theorem 1's proof step: the modified objective
    ‖Pᵀ(K − CUCᵀ − δI)P‖ is (near) zero at the closed-form solution."""
    n, kspikes, theta = 64, 4, 0.3
    kmat = _spiked_spsd(rng, n, kspikes, theta)
    cols = list(range(0, n, 8))
    approx, _ = _ss_spsd_approx(kmat, cols, rank_rtol=1e-2)
    sub = (kmat - approx)[np.ix_(cols, cols)]
    assert np.linalg.norm(sub, 2) < 0.05 * np.linalg.norm(
        kmat[np.ix_(cols, cols)], 2)


# ---------------------------------------------------------------------------
# Figure 2: spectrum of the approximation has no long tail
# ---------------------------------------------------------------------------


def test_figure2_spectrum_no_long_tail(rng):
    """The SS approximation's spectrum keeps a flat δ floor (every
    eigenvalue ≥ δ−ε), unlike Nystrom whose eigenvalues collapse to 0
    after index c — the paper's Figure 2 claim, on an explicit SPSD K."""
    n, kspikes, theta = 96, 5, 0.4
    kmat = _spiked_spsd(rng, n, kspikes, theta)
    cols = list(range(0, n, 6))
    # In the c×c principal submatrix the spikes are diluted (σmax ≈ 1.45)
    # while the flat tail stays at θ=0.4, so tail/top ≈ 0.28. The rank
    # tolerance must sit above that ratio to classify the tail as
    # "discarded" — the hyperparameter the paper leaves unstated (we
    # expose it as rank_rtol; see the ablation bench E9).
    approx_ss, delta = _ss_spsd_approx(kmat, cols, rank_rtol=0.35)
    approx_ny = _nystrom_spsd_approx(kmat, cols)
    ev_ss = np.sort(np.linalg.eigvalsh((approx_ss + approx_ss.T) / 2))
    ev_ny = np.sort(np.linalg.eigvalsh((approx_ny + approx_ny.T) / 2))
    assert delta > 0.05, delta
    # Nystrom: rank ≤ c ⇒ at least n−c near-zero eigenvalues
    assert np.sum(np.abs(ev_ny) < 1e-8) >= n - len(cols)
    # SS: the shifted identity lifts the entire tail to ≈ δ
    assert ev_ss[0] > 0.5 * delta


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(5, 8), c=st.sampled_from([8, 16, 32]),
       d=st.sampled_from([8, 32]), dtype=st.sampled_from(["f32", "bf16"]))
def test_hypothesis_ss(logn, c, d, dtype):
    n = 2 ** logn
    rng = np.random.default_rng(n * 3 + c + d)
    q, k, v = make_qkv(rng, n, d)
    if dtype == "bf16":
        qj, kj, vj = (jnp.asarray(x, jnp.bfloat16) for x in (q, k, v))
        tol = dict(rtol=5e-2, atol=5e-2)
    else:
        qj, kj, vj = (jnp.asarray(x) for x in (q, k, v))
        tol = dict(rtol=2e-4, atol=2e-4)
    got = np.asarray(spectral_shift_attention_pallas(qj, kj, vj, c),
                     np.float32)
    want = np.asarray(ref.spectral_shift_attention_ns(
        qj.astype(jnp.float32), kj.astype(jnp.float32),
        vj.astype(jnp.float32), c), np.float32)
    np.testing.assert_allclose(got, want, **tol)
