"""AOT pipeline: lowering to HLO text must succeed and stay LAPACK-free."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def tiny_cfg(attention="ss", seq=32):
    return M.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=1,
                         d_ff=64, seq_len=seq, attention=attention,
                         landmarks=8, pinv_iters=4,
                         block_q=32, block_k=32).validate()


@pytest.mark.parametrize("attention", ["full", "nystrom", "ss"])
def test_encode_lowers_to_hlo_text(attention):
    cfg = tiny_cfg(attention)
    text = aot.to_hlo_text(aot.lower_encode(cfg, batch=2))
    assert "ENTRY" in text and "HloModule" in text
    # the artifact path must avoid LAPACK custom-calls (old runtime)
    assert "lapack" not in text.lower()
    assert "custom-call" not in text.lower()


def test_train_step_lowers_to_hlo_text():
    cfg = tiny_cfg("ss")
    text = aot.to_hlo_text(aot.lower_train_step(cfg, batch=2))
    assert "ENTRY" in text
    assert "lapack" not in text.lower()
    assert "custom-call" not in text.lower()


def test_hlo_text_roundtrips_through_xla_parser():
    """The text must parse back into an XlaComputation (what the rust
    loader does with HloModuleProto::from_text_file)."""
    from jax._src.lib import xla_client as xc
    cfg = tiny_cfg("ss")
    text = aot.to_hlo_text(aot.lower_encode(cfg, batch=2))
    # round-trip through the python xla client's text parser
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


def test_base_config_is_valid():
    for variant in aot.VARIANTS:
        for seq in aot.ENCODE_SEQS:
            cfg = aot.base_config(variant, seq)
            assert cfg.seq_len == seq
            assert cfg.attention == variant


def test_quick_manifest_structure(tmp_path, monkeypatch):
    """--quick end-to-end on a tiny model: files + manifest exist."""
    monkeypatch.setattr(aot, "base_config", lambda v, s: tiny_cfg(v, 32))
    monkeypatch.setattr(aot, "ENCODE_SEQS", (32,))
    monkeypatch.setattr(aot, "TRAIN_SEQ", 32)
    monkeypatch.setattr(aot, "TRAIN_BATCH", 2)
    monkeypatch.setattr(aot, "ENCODE_BATCH", 2)
    import sys
    monkeypatch.setattr(sys, "argv",
                        ["aot", "--out-dir", str(tmp_path), "--quick"])
    aot.main()
    names = {p.name for p in tmp_path.iterdir()}
    assert "manifest.txt" in names and "init_params.bin" in names
    assert "encode_ss_n32_b2.hlo.txt" in names
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "param_count=" in manifest
    assert "artifact kind=train_step variant=ss" in manifest
    # init params byte-length matches param_count
    pcount = int([l for l in manifest.splitlines()
                  if l.startswith("param_count=")][0].split("=")[1])
    assert (tmp_path / "init_params.bin").stat().st_size == 4 * pcount
