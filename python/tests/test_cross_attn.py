"""Landmark cross-attention kernel W = L(Q̃Kᵀ)V (the streamed B-factor)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import landmark_cross_attention_pallas, ref
from .conftest import make_qkv


def _want(qt, k, v, scale=None):
    if scale is None:
        scale = 1.0 / np.sqrt(qt.shape[-1])
    b = jax.nn.softmax((qt @ k.T) * scale, axis=-1)
    return b @ v


@pytest.mark.parametrize("n,c,d", [(128, 16, 32), (256, 32, 64), (512, 64, 32)])
@pytest.mark.parametrize("bk", [64, 128])
def test_matches_dense_composition(rng, n, c, d, bk):
    q, k, v = make_qkv(rng, n, d)
    qt = ref.segment_means(jnp.asarray(q), c)
    got = landmark_cross_attention_pallas(qt, jnp.asarray(k), jnp.asarray(v),
                                          block_k=bk)
    want = _want(np.asarray(qt), k, v)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-5)


def test_blocking_invariance(rng):
    q, k, v = make_qkv(rng, 256, 16)
    qt = ref.segment_means(jnp.asarray(q), 8)
    outs = [np.asarray(landmark_cross_attention_pallas(
        qt, jnp.asarray(k), jnp.asarray(v), block_k=bk))
        for bk in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-5)


def test_figure1_constraint(rng):
    """Figure 1 of the paper: row softmax needs ALL columns. Computing
    softmax over only a key subset then averaging does NOT equal the
    streamed full-row result — this is why the kernel must accumulate
    the online normalizer across every block."""
    q, k, v = make_qkv(rng, 128, 16)
    qt = np.asarray(ref.segment_means(jnp.asarray(q), 8))
    full = _want(qt, k, v)
    half = _want(qt, k[:64], v[:64])  # softmax over half the columns
    assert np.max(np.abs(np.asarray(full) - np.asarray(half))) > 1e-2


def test_large_scores_stable(rng):
    q, k, v = make_qkv(rng, 128, 8, scale=25.0)
    qt = ref.segment_means(jnp.asarray(q), 8)
    out = np.asarray(landmark_cross_attention_pallas(qt, jnp.asarray(k),
                                                     jnp.asarray(v)))
    assert np.isfinite(out).all()


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(5, 9), c=st.sampled_from([4, 16, 32]),
       d=st.sampled_from([8, 32]))
def test_hypothesis(logn, c, d):
    n = 2 ** logn
    rng = np.random.default_rng(n + c + d)
    q, k, v = make_qkv(rng, n, d)
    qt = ref.segment_means(jnp.asarray(q), c)
    got = np.asarray(landmark_cross_attention_pallas(qt, jnp.asarray(k),
                                                     jnp.asarray(v)))
    want = np.asarray(_want(np.asarray(qt), k, v))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-5)
